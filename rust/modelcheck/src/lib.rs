//! Loom model checks for the memo/cache concurrency layer.
//!
//! This crate includes the *production* source of `optcnn::util::sync`
//! via `#[path]` and rebuilds it against `loom::sync`, so every
//! interleaving loom explores is explored over the exact code the memo
//! (`cost::memo::TableMemo`) and the plan service's state memo run in
//! normal builds. Run with:
//!
//! ```text
//! cd rust/modelcheck
//! RUSTFLAGS="--cfg loom" cargo test --release
//! ```
//!
//! Without `--cfg loom` the models compile away and `cargo test` passes
//! vacuously (plus the facade's own std-based unit tests); the CI
//! `modelcheck` job always sets the flag.
//!
//! Invariants proven (DESIGN.md §10):
//!
//! * a single-flight cell runs its initializer exactly once, and every
//!   waiter observes the winner's value;
//! * concurrent memo users funnel into one build per key;
//! * `forget` after a failed build re-opens the key without ever
//!   evicting a successor cell (the `Arc::ptr_eq` guard);
//! * the sharded check-then-act insert pattern used by plan ingestion
//!   never loses an insert.

#[path = "../../src/util/sync.rs"]
pub mod sync;

#[cfg(all(test, loom))]
mod models {
    use super::sync::{lock, Arc, Mutex, OnceCell, SingleFlightLru};
    use loom::sync::atomic::{AtomicUsize, Ordering};
    use loom::thread;

    #[test]
    fn once_cell_runs_exactly_one_initializer_across_threads() {
        loom::model(|| {
            let cell: Arc<OnceCell<usize>> = Arc::new(OnceCell::new());
            let runs = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..2)
                .map(|i| {
                    let cell = Arc::clone(&cell);
                    let runs = Arc::clone(&runs);
                    thread::spawn(move || {
                        cell.get_or_init(|| {
                            runs.fetch_add(1, Ordering::SeqCst);
                            i
                        })
                    })
                })
                .collect();
            let results: Vec<(usize, bool)> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            assert_eq!(runs.load(Ordering::SeqCst), 1, "initializer ran more than once");
            assert_eq!(
                results.iter().filter(|(_, ran)| *ran).count(),
                1,
                "exactly one caller must report having run the initializer"
            );
            let winner = results.iter().find(|(_, ran)| *ran).map(|(v, _)| *v).unwrap();
            assert!(
                results.iter().all(|(v, _)| *v == winner),
                "all callers must observe the winning value"
            );
            assert!(cell.is_set());
        });
    }

    #[test]
    fn memo_single_flight_builds_each_key_exactly_once() {
        loom::model(|| {
            // The exact shape of `TableMemo` / the service's state memo:
            // a mutex-guarded LRU handing out cells, initialized outside
            // the container lock.
            let lru = Arc::new(Mutex::new(SingleFlightLru::<u32, u32>::new(2)));
            let builds = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let lru = Arc::clone(&lru);
                    let builds = Arc::clone(&builds);
                    thread::spawn(move || {
                        let cell = lock(&lru).cell(&7);
                        let (v, _) = cell.get_or_init(|| {
                            builds.fetch_add(1, Ordering::SeqCst);
                            42
                        });
                        v
                    })
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), 42);
            }
            assert_eq!(builds.load(Ordering::SeqCst), 1, "duplicate build for one key");
        });
    }

    #[test]
    fn stale_forget_never_evicts_a_successor_cell() {
        loom::model(|| {
            let lru = Arc::new(Mutex::new(SingleFlightLru::<u32, u32>::new(2)));
            // A build failed: its cell was handed out, then forgotten.
            let stale = lock(&lru).cell(&1);
            lock(&lru).forget(&1, &stale);
            // Race a retry (fresh cell, successful build) against a
            // second, stale forget still holding the old handle.
            let retry = {
                let lru = Arc::clone(&lru);
                thread::spawn(move || lock(&lru).cell(&1).get_or_init(|| 42).0)
            };
            let raced = {
                let lru = Arc::clone(&lru);
                let stale = Arc::clone(&stale);
                thread::spawn(move || lock(&lru).forget(&1, &stale))
            };
            assert_eq!(retry.join().unwrap(), 42);
            raced.join().unwrap();
            // In every interleaving the stale forget is a no-op (the
            // Arc::ptr_eq guard), so the successor's value survives.
            let (v, ran) = lock(&lru).cell(&1).get_or_init(|| 7);
            assert_eq!((v, ran), (42, false), "stale forget evicted the successor");
        });
    }

    #[test]
    fn sharded_cache_never_loses_an_insert() {
        loom::model(|| {
            // Plan ingestion's check-then-act: lookup under one lock
            // acquisition, verify unlocked, insert under a second. Two
            // concurrent ingests of the same (equal) plan may both miss
            // and both insert; the entry must survive with the shared
            // value either way.
            let shard = Arc::new(Mutex::new(std::collections::HashMap::<u32, u32>::new()));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let shard = Arc::clone(&shard);
                    thread::spawn(move || {
                        let hit = lock(&shard).get(&7).copied();
                        if hit.is_none() {
                            lock(&shard).insert(7, 42);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let map = lock(&shard);
            assert_eq!(map.len(), 1);
            assert_eq!(map.get(&7), Some(&42), "insert lost");
        });
    }
}
