//! Property-based tests over the search machinery (the paper's theorems),
//! using the in-tree `prop` mini-framework (no proptest in the offline
//! registry; failing cases print a replay seed).

use optcnn::cost::{CostModel, CostTables};
use optcnn::device::DeviceGraph;
use optcnn::graph::{CompGraph, GraphBuilder, PoolKind};
use optcnn::optimizer::{self, dfs, strategies};
use optcnn::parallel::{enumerate_configs, input_region, output_tiles, PConfig};
use optcnn::prop::{forall, Gen};
use optcnn::tensor::{Region, Tensor};

/// A random small CNN: a chain of conv/pool/fc stages with an optional
/// two-way branch joined by a concat (exercises edge elimination).
fn random_net(g: &mut Gen) -> CompGraph {
    let mut b = GraphBuilder::new("random");
    let batch = *g.choose(&[2usize, 4, 8]);
    let mut cur = b.input(batch, *g.choose(&[1usize, 3]), 16, 16).unwrap();
    let depth = g.usize_in(1, 4);
    for i in 0..depth {
        let branchy = g.bool() && i == 0;
        if branchy {
            let c1 = b
                .conv2d(&format!("bl{i}"), cur, *g.choose(&[4usize, 8]), (3, 3), (1, 1), (1, 1))
                .unwrap();
            let c2 = b
                .conv2d(&format!("br{i}"), cur, *g.choose(&[4usize, 8]), (1, 1), (1, 1), (0, 0))
                .unwrap();
            cur = b.concat(&format!("cat{i}"), &[c1, c2]).unwrap();
        } else {
            cur = b
                .conv2d(&format!("c{i}"), cur, *g.choose(&[4usize, 6, 8]), (3, 3), (1, 1), (1, 1))
                .unwrap();
        }
        cur = b.pool2d(&format!("p{i}"), cur, PoolKind::Max, (2, 2), (2, 2), (0, 0)).unwrap();
    }
    let f = b.fully_connected("fc", cur, *g.choose(&[10usize, 12])).unwrap();
    b.softmax("sm", f).unwrap();
    b.finish().unwrap()
}

#[test]
fn elimination_dp_equals_exhaustive_search() {
    // Theorems 1 & 2, end to end: on random graphs the DP optimum equals
    // brute force (branch-and-bound, run to completion).
    forall("dp == dfs on random nets", 25, |g| {
        let net = random_net(g);
        let ndev = 2;
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&net, &d);
        let tables = CostTables::build(&cm, ndev).unwrap();
        let dp = optimizer::optimize(&tables);
        let brute = dfs::dfs_optimal(&tables, None);
        assert!(brute.complete, "random net too large for exhaustive search");
        assert!(
            (dp.cost - brute.cost).abs() <= 1e-9 * brute.cost.max(1e-12),
            "dp {} != dfs {} on {} layers",
            dp.cost,
            brute.cost,
            net.num_layers()
        );
    });
}

#[test]
fn optimum_never_worse_than_baselines() {
    forall("optimum <= baselines", 20, |g| {
        let net = random_net(g);
        let ndev = 2;
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&net, &d);
        let tables = CostTables::build(&cm, ndev).unwrap();
        let opt = optimizer::optimize(&tables);
        for name in ["data", "model", "owt"] {
            let s = strategies::by_name(name, &net, ndev).unwrap();
            assert!(opt.cost <= cm.t_o(&s) * (1.0 + 1e-9));
        }
    });
}

#[test]
fn tiles_partition_output_exactly() {
    // Equal partitioning: tiles are disjoint and cover the tensor.
    forall("tiles partition", 200, |g| {
        let shape: Vec<usize> = vec![
            g.divisor_of(24) * 2,
            g.usize_in(1, 16),
            g.usize_in(1, 20),
            g.usize_in(1, 20),
        ];
        let cfg = PConfig::new(
            g.divisor_of(shape[0]),
            g.divisor_of(shape[1]),
            g.divisor_of(shape[2]),
            g.divisor_of(shape[3]),
        );
        let tiles = output_tiles(&shape, &cfg);
        assert_eq!(tiles.len(), cfg.total());
        let vol: usize = tiles.iter().map(|t| t.volume()).sum();
        assert_eq!(vol, shape.iter().product::<usize>());
        for i in 0..tiles.len() {
            for j in i + 1..tiles.len() {
                assert_eq!(tiles[i].overlap_volume(&tiles[j]), 0);
            }
        }
    });
}

#[test]
fn enumerated_configs_are_legal_and_complete() {
    forall("config enumeration", 50, |g| {
        let net = random_net(g);
        let ndev = g.usize_in(1, 5);
        for l in &net.layers {
            let cfgs = enumerate_configs(l, ndev);
            assert!(!cfgs.is_empty());
            for c in &cfgs {
                assert!(c.total() <= ndev);
                for d in 0..l.out_shape.len() {
                    assert_eq!(l.out_shape[d] % c.deg[d], 0);
                }
            }
            // serial is always present exactly once
            assert_eq!(cfgs.iter().filter(|c| **c == PConfig::serial()).count(), 1);
        }
    });
}

#[test]
fn input_regions_cover_what_tiles_need() {
    // Union of input regions must cover the full input tensor (every
    // input element feeds some output tile) for conv/pool/fc layers.
    forall("input coverage", 50, |g| {
        let net = random_net(g);
        let ndev = *g.choose(&[2usize, 4]);
        for l in &net.layers {
            if l.in_shapes.is_empty() {
                continue;
            }
            let cfgs = enumerate_configs(l, ndev);
            let cfg = *g.choose(&cfgs);
            let tiles = output_tiles(&l.out_shape, &cfg);
            for in_idx in 0..l.in_shapes.len() {
                let mut covered = Tensor::zeros(&l.in_shapes[in_idx]);
                for t in &tiles {
                    if let Some(r) = input_region(l, in_idx, t) {
                        let ones = Tensor::from_fn(&r.extents(), |_| 1.0);
                        covered.insert(&r, &ones);
                    }
                }
                assert!(
                    covered.data().iter().all(|&v| v == 1.0),
                    "uncovered input of {} under {}",
                    l.name,
                    cfg.label()
                );
            }
        }
    });
}

#[test]
fn region_algebra() {
    forall("region algebra", 300, |g| {
        fn mk(g: &mut Gen) -> Region {
            let s1 = g.usize_in(0, 10);
            let s2 = g.usize_in(0, 10);
            let e1 = g.usize_in(1, 8);
            let e2 = g.usize_in(1, 8);
            Region::new(&[(s1, s1 + e1), (s2, s2 + e2)])
        }
        let a = mk(g);
        let b = mk(g);
        // intersection is commutative and bounded
        assert_eq!(a.overlap_volume(&b), b.overlap_volume(&a));
        assert!(a.overlap_volume(&b) <= a.volume().min(b.volume()));
        match a.intersect(&b) {
            Some(i) => {
                assert_eq!(i.volume(), a.overlap_volume(&b));
                assert!(a.contains(&i) && b.contains(&i));
            }
            None => assert_eq!(a.overlap_volume(&b), 0),
        }
        // localize preserves volume
        if a.contains(&b) {
            assert_eq!(a.localize(&b).volume(), b.volume());
        }
    });
}

#[test]
fn slice_insert_roundtrip_random() {
    forall("slice/insert roundtrip", 100, |g| {
        let shape = vec![g.usize_in(1, 6), g.usize_in(1, 6), g.usize_in(1, 8)];
        let t = {
            let mut rng = g.rng().clone();
            Tensor::from_fn(&shape, |_| rng.next_f32())
        };
        let ranges: Vec<(usize, usize)> = shape
            .iter()
            .map(|&n| {
                let s = g.usize_in(0, n);
                let len = g.usize_in(1, n - s + 1);
                (s, s + len)
            })
            .collect();
        let r = Region::new(&ranges);
        let block = t.slice(&r);
        let mut t2 = t.clone();
        t2.insert(&r, &block);
        assert_eq!(t, t2, "insert of own slice is identity");
    });
}

#[test]
fn json_roundtrip_random() {
    use optcnn::util::json::Json;
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 5) } else { g.usize_in(0, 7) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.usize_in(0, 10_000) as f64) / 8.0),
            3 => Json::Str(format!("k{}-π-\"q\"", g.usize_in(0, 99))),
            4 => Json::Num(-(g.usize_in(0, 100) as f64)),
            5 => {
                let n = g.usize_in(0, 4);
                Json::Arr(g.vec(n, |g| random_json(g, depth - 1)))
            }
            _ => {
                let n = g.usize_in(0, 4);
                let mut m = std::collections::BTreeMap::new();
                for i in 0..n {
                    m.insert(format!("f{i}"), random_json(g, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    forall("json roundtrip", 200, |g| {
        let v = random_json(g, 3);
        let parsed = Json::parse(&v.to_string()).expect("parse own output");
        assert_eq!(parsed, v);
    });
}

#[test]
fn strategy_cost_table_consistency() {
    // Tabled strategy cost must equal direct Eq.1 evaluation for random
    // strategies (not just the optimum).
    forall("tables == direct", 20, |g| {
        let net = random_net(g);
        let ndev = 2;
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&net, &d);
        let tables = CostTables::build(&cm, ndev).unwrap();
        let idx: Vec<usize> =
            (0..net.num_layers()).map(|l| g.usize_in(0, tables.num_configs(l))).collect();
        let s = tables.strategy_from_indices(&idx);
        let direct = cm.t_o(&s);
        let tabled = tables.strategy_cost(&idx);
        assert!((direct - tabled).abs() <= 1e-9 * direct.max(1e-12));
    });
}
