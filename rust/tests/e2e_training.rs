//! End-to-end partitioned-training integration tests.
//!
//! These exercise the whole stack: AOT artifacts (L1 Pallas kernels inside
//! L2 JAX layer functions, lowered to HLO text) executed through PJRT by
//! per-device worker threads under the L3 coordinator, for several
//! parallelization strategies — and pin the paper's central claim: every
//! strategy computes the same network (identical losses / parameters).
//!
//! Requires `make artifacts`. Tests self-skip with a notice when the
//! artifact directory is absent so plain `cargo test` works in a fresh
//! clone.

use optcnn::data::SyntheticDataset;
use optcnn::exec::{OracleTrainer, Trainer};
use optcnn::graph::nets;
use optcnn::optimizer::strategies;
use optcnn::parallel::{PConfig, Strategy};
use optcnn::runtime::ArtifactStore;

const BATCH: usize = 32;
const NDEV: usize = 4;
const LR: f32 = 0.01;

fn store() -> Option<ArtifactStore> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    match ArtifactStore::load(dir) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping e2e test (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn dataset() -> SyntheticDataset {
    SyntheticDataset::new(10, 3, 32, 32, 0.3, 1234)
}

/// A mixed layer-wise strategy exercising sample, channel, AND spatial
/// partitioning in one run (the paper's "hidden dimensions").
fn mixed_strategy() -> Strategy {
    let g = nets::minicnn(BATCH).unwrap();
    let mut cfgs = vec![PConfig::serial(); g.num_layers()];
    for l in &g.layers {
        cfgs[l.id] = match l.name.as_str() {
            "input" => PConfig::data(4),
            "conv1" => PConfig::new(2, 1, 2, 1),      // sample x height
            "pool1" => PConfig::new(1, 1, 2, 2),      // spatial
            "conv2" => PConfig::new(1, 2, 1, 2),      // channel x width
            "pool2" => PConfig::new(4, 1, 1, 1),      // sample
            "fc1" => PConfig::new(2, 2, 1, 1),        // sample x channel
            "fc2" => PConfig::channel(2),             // channel
            "softmax" => PConfig::data(4),
            _ => PConfig::serial(),
        };
    }
    Strategy { configs: cfgs }
}

#[test]
fn data_parallel_matches_oracle() {
    let Some(store) = store() else { return };
    let g = nets::minicnn(BATCH).unwrap();
    let strat = strategies::data_parallel(&g, NDEV);
    let mut trainer = Trainer::new(&store, g, strat, NDEV, LR, 7).unwrap();
    let mut oracle =
        OracleTrainer::new(&store, "minicnn", BATCH, trainer.master_params(), LR).unwrap();
    let ds = dataset();
    for step in 0..4 {
        let (x, y) = ds.batch(step, BATCH);
        let l_par = trainer.step(&x, &y).unwrap();
        let l_ser = oracle.step(&x, &y).unwrap();
        assert!(
            (l_par - l_ser).abs() < 2e-4 * l_ser.abs().max(1.0),
            "step {step}: partitioned {l_par} vs oracle {l_ser}"
        );
    }
    // parameters agree after training
    let m = trainer.master_params();
    for (a, b) in m.iter().zip(oracle.params()) {
        assert!(a.allclose(b, 2e-4), "param drift: {}", a.max_abs_diff(b));
    }
}

#[test]
fn mixed_layerwise_strategy_matches_oracle() {
    let Some(store) = store() else { return };
    let g = nets::minicnn(BATCH).unwrap();
    let mut trainer = Trainer::new(&store, g, mixed_strategy(), NDEV, LR, 9).unwrap();
    let mut oracle =
        OracleTrainer::new(&store, "minicnn", BATCH, trainer.master_params(), LR).unwrap();
    let ds = dataset();
    for step in 0..4 {
        let (x, y) = ds.batch(step, BATCH);
        let l_par = trainer.step(&x, &y).unwrap();
        let l_ser = oracle.step(&x, &y).unwrap();
        assert!(
            (l_par - l_ser).abs() < 5e-4 * l_ser.abs().max(1.0),
            "step {step}: mixed {l_par} vs oracle {l_ser}"
        );
    }
}

#[test]
fn all_baseline_strategies_compute_identical_losses() {
    let Some(store) = store() else { return };
    let ds = dataset();
    let mut curves: Vec<Vec<f32>> = Vec::new();
    for name in ["data", "model", "owt"] {
        let g = nets::minicnn(BATCH).unwrap();
        let strat = strategies::by_name(name, &g, NDEV).unwrap();
        let mut trainer = Trainer::new(&store, g, strat, NDEV, LR, 11).unwrap();
        let mut curve = Vec::new();
        for step in 0..3 {
            let (x, y) = ds.batch(step, BATCH);
            curve.push(trainer.step(&x, &y).unwrap());
        }
        curves.push(curve);
    }
    for c in &curves[1..] {
        for (a, b) in c.iter().zip(curves[0].iter()) {
            assert!((a - b).abs() < 5e-4 * b.abs().max(1.0), "{curves:?}");
        }
    }
}

#[test]
fn training_reduces_loss() {
    let Some(store) = store() else { return };
    let g = nets::minicnn(BATCH).unwrap();
    let strat = strategies::owt(&g, NDEV);
    let mut trainer = Trainer::new(&store, g, strat, NDEV, LR, 3).unwrap();
    let ds = dataset();
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..25 {
        let (x, y) = ds.batch(step % 4, BATCH);
        let l = trainer.step(&x, &y).unwrap();
        if step == 0 {
            first = l;
        }
        last = l;
    }
    assert!(last < 0.5 * first, "loss did not decrease: {first} -> {last}");
    assert!(trainer.comm.total() > 0, "communication should be accounted");
}

#[test]
fn optimizer_strategy_is_executable() {
    // The full pipeline: Planner session search -> executable strategy.
    let Some(store) = store() else { return };
    use optcnn::planner::{Network, Planner, StrategyKind};
    let mut p = Planner::builder(Network::MiniCnn)
        .devices(NDEV)
        .per_gpu_batch(BATCH / NDEV)
        .build()
        .unwrap();
    let strategy = p.strategy(StrategyKind::Layerwise).unwrap();
    let g = nets::minicnn(BATCH).unwrap();
    let mut trainer = Trainer::new(&store, g, strategy, NDEV, LR, 5).unwrap();
    let ds = dataset();
    let (x, y) = ds.batch(0, BATCH);
    let loss = trainer.step(&x, &y).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn missing_artifact_is_reported_clearly() {
    let Some(store) = store() else { return };
    // batch 48 tiles (nt=12) were never generated
    let g = nets::minicnn(48).unwrap();
    let strat = strategies::data_parallel(&g, NDEV);
    let err = match Trainer::new(&store, g, strat, NDEV, LR, 1) {
        Err(e) => e,
        Ok(_) => panic!("expected missing-artifact error"),
    };
    assert!(format!("{err:#}").contains("missing artifact"), "{err:#}");
}
