//! Integration tests over the full search/evaluation pipeline: the
//! paper's qualitative claims, pinned as assertions so regressions in the
//! cost model or optimizer surface immediately. All end-to-end queries go
//! through the typed [`Planner`] session API.

use optcnn::cost::{CostModel, CostTables, SyncModel};
use optcnn::device::DeviceGraph;
use optcnn::graph::{nets, OpKind};
use optcnn::metrics::comm_volume;
use optcnn::optimizer::{self, strategies};
use optcnn::parallel::PConfig;
use optcnn::planner::{Network, Planner, StrategyKind};

fn planner(net: Network, ndev: usize) -> Planner {
    Planner::builder(net).devices(ndev).build().unwrap()
}

#[test]
fn fig2_channel_beats_sample_for_fc6() {
    // Figure 2: channel parallelism slashes fc6 communication.
    let g = nets::vgg16(64).unwrap();
    let d = DeviceGraph::p100_cluster(2).unwrap();
    let cm = CostModel::new(&g, &d);
    let fc6 = g.layers.iter().find(|l| l.name == "fc6").unwrap();
    let pool5 = g.layers.iter().find(|l| l.name == "pool5").unwrap();
    let sample = cm.s_bytes(fc6, &PConfig::data(2))
        + cm.x_bytes(pool5, fc6, 0, &PConfig::data(2), &PConfig::data(2));
    let channel = cm.s_bytes(fc6, &PConfig::channel(2))
        + cm.x_bytes(pool5, fc6, 0, &PConfig::data(2), &PConfig::channel(2));
    assert!(sample > 10.0 * channel, "paper: ~12x; got {}", sample / channel);
}

#[test]
fn fig3_degree_optima() {
    // Figure 3: early conv prefers all 16 devices; the classifier FC
    // prefers a small degree.
    let g = nets::inception_v3(32 * 16).unwrap();
    let d = DeviceGraph::p100_cluster(16).unwrap();
    let cm = CostModel::new(&g, &d);
    let conv = g.layers.iter().find(|l| l.name == "stem_conv3").unwrap();
    let fc = g.layers.iter().find(|l| l.name == "fc").unwrap();
    let best = |l: &optcnn::graph::Layer| {
        [1usize, 2, 4, 8, 16]
            .into_iter()
            .min_by(|&a, &b| {
                let t = |k: usize| {
                    cm.t_c(l, &PConfig::data(k)) + cm.t_s(l, &PConfig::data(k))
                };
                t(a).partial_cmp(&t(b)).unwrap()
            })
            .unwrap()
    };
    assert_eq!(best(conv), 16, "conv should want all devices");
    let fc_best = best(fc);
    assert!((2..=4).contains(&fc_best), "fc should want a small degree, got {fc_best}");
}

#[test]
fn table5_regime_transitions() {
    // Table 5: data parallelism early, mixed/model parallelism late.
    let mut p = planner(Network::Vgg16, 4);
    let s = p.strategy(StrategyKind::Layerwise).unwrap();
    let g = p.graph();
    let conv1 = g.layers.iter().find(|l| l.name == "conv1").unwrap();
    let fc6 = g.layers.iter().find(|l| l.name == "fc6").unwrap();
    let fc8 = g.layers.iter().find(|l| l.name == "fc8").unwrap();
    assert_eq!(s.config(conv1.id).deg[0], 4, "early conv: sample parallelism");
    assert!(s.config(fc6.id).deg[1] > 1, "fc: channel parallelism");
    assert_eq!(s.config(fc8.id).deg[0], 1, "fc: no sample replication");
    // at least one layer uses a mixed/hidden-dimension configuration
    assert!(
        g.layers.iter().any(|l| {
            let c = s.config(l.id);
            let dims_used = (0..4).filter(|&d| c.deg[d] > 1).count();
            dims_used >= 2 || c.deg[2] > 1 || c.deg[3] > 1
        }),
        "optimum should exploit hidden dimensions"
    );
}

#[test]
fn fig7_ordering_at_scale() {
    // Figure 7's strategy ordering at 16 GPUs: layerwise >= owt >= data
    // >> model for the paper's three networks.
    for net in [Network::AlexNet, Network::Vgg16, Network::InceptionV3] {
        let mut p = planner(net, 16);
        let lw = p.evaluate(StrategyKind::Layerwise).unwrap().throughput;
        let owt = p.evaluate(StrategyKind::Owt).unwrap().throughput;
        let data = p.evaluate(StrategyKind::Data).unwrap().throughput;
        let model = p.evaluate(StrategyKind::Model).unwrap().throughput;
        assert!(lw >= owt * (1.0 - 1e-9), "{net}: lw {lw} < owt {owt}");
        assert!(owt > data, "{net}: owt {owt} <= data {data}");
        assert!(data > model, "{net}: data {data} <= model {model}");
    }
}

#[test]
fn fig8_owt_and_layerwise_cut_communication() {
    // Figure 8: OWT and layer-wise dramatically reduce communication
    // versus data/model parallelism on parameter-heavy networks.
    for net in [Network::AlexNet, Network::Vgg16] {
        let mut p = planner(net, 16);
        let mut vol = |kind: StrategyKind| {
            let s = p.strategy(kind).unwrap();
            let cm = CostModel::new(p.graph(), p.device_graph());
            comm_volume(&cm, &s).total()
        };
        let (data, owt, lw) =
            (vol(StrategyKind::Data), vol(StrategyKind::Owt), vol(StrategyKind::Layerwise));
        assert!(data > 3.0 * owt, "{net}: data {data} vs owt {owt}");
        assert!(data > 3.0 * lw, "{net}: data {data} vs lw {lw}");
    }
}

#[test]
fn scalability_headline() {
    // Figure 7 headline: layer-wise reaches >= 10x at 16 GPUs on every
    // network, and data parallelism falls well short on AlexNet.
    for net in [Network::AlexNet, Network::Vgg16, Network::InceptionV3] {
        let base = planner(net, 1).evaluate(StrategyKind::Data).unwrap().throughput;
        let lw =
            planner(net, 16).evaluate(StrategyKind::Layerwise).unwrap().throughput / base;
        assert!(lw >= 10.0, "{net}: layerwise speedup {lw}");
    }
    let base = planner(Network::AlexNet, 1).evaluate(StrategyKind::Data).unwrap().throughput;
    let dp = planner(Network::AlexNet, 16).evaluate(StrategyKind::Data).unwrap().throughput
        / base;
    assert!(dp < 6.0, "alexnet data-parallel speedup should collapse, got {dp}");
}

#[test]
fn k_equals_2_for_all_benchmark_networks() {
    // Paper: every evaluated CNN reduces to a 2-node final graph.
    for net in [
        Network::LeNet5,
        Network::AlexNet,
        Network::Vgg16,
        Network::InceptionV3,
        Network::ResNet18,
    ] {
        let mut p = planner(net, 2);
        let opt = p.optimize().unwrap();
        assert_eq!(opt.stats.final_nodes, 2, "{net} must reduce to K=2");
        assert_eq!(
            p.session_stats().searches,
            1,
            "{net}: a session runs the search exactly once"
        );
    }
}

#[test]
fn central_ps_changes_the_optimum_but_not_correctness() {
    // The sync-protocol ablation: under a central PS, replication gets
    // more expensive, so the optimum shifts away from data parallelism —
    // but it must still beat every baseline under the same model.
    let g = nets::alexnet(32 * 4).unwrap();
    let d = DeviceGraph::p100_cluster(4).unwrap();
    let cm = CostModel::new(&g, &d).with_sync(SyncModel::Central);
    let tables = CostTables::build(&cm, 4).unwrap();
    let opt = optimizer::optimize(&tables);
    for name in ["data", "model", "owt"] {
        let s = strategies::by_name(name, &g, 4).unwrap();
        assert!(opt.cost <= cm.t_o(&s) * (1.0 + 1e-9), "central-PS optimum lost to {name}");
    }
}

#[test]
fn measured_tc_override_flows_through() {
    // The measured-profile hook: overriding t_C changes strategy costs.
    let g = nets::lenet5(32).unwrap();
    let d = DeviceGraph::p100_cluster(2).unwrap();
    let mut cm = CostModel::new(&g, &d);
    let base_tables = CostTables::build(&cm, 2).unwrap();
    let zeroed: Vec<Vec<f64>> =
        base_tables.configs.iter().map(|cfgs| vec![0.0; cfgs.len()]).collect();
    cm.measured_tc = Some(zeroed);
    let tables = CostTables::build(&cm, 2).unwrap();
    let opt = optimizer::optimize(&tables);
    let base = optimizer::optimize(&base_tables);
    assert!(opt.cost < base.cost, "zeroed compute must lower the optimum");
}

#[test]
fn per_layer_costs_are_finite_and_positive() {
    for net in ["alexnet", "vgg16", "inception_v3", "resnet18"] {
        let g = nets::by_name(net, 128).unwrap();
        let d = DeviceGraph::p100_cluster(4).unwrap();
        let cm = CostModel::new(&g, &d);
        for l in &g.layers {
            if matches!(l.op, OpKind::Input) {
                continue;
            }
            let t = cm.t_c(l, &PConfig::data(4));
            assert!(t.is_finite() && t > 0.0, "{net}/{}: t_c {t}", l.name);
        }
    }
}
