//! Concurrency suite for the `PlanService` + `optcnn serve` subsystem:
//! N threads hammering one `Arc<PlanService>` must receive byte-identical
//! answers to one-shot single-threaded `Planner` sessions, the
//! single-flight memo must build shared state exactly once under races,
//! shard counters must sum coherently, and the TCP server must answer a
//! round-trip over a real socket.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};

use optcnn::graph::GraphBuilder;
use optcnn::planner::serve;
use optcnn::planner::{Network, NetworkSpec, PlanRequest, PlanService, Planner, StrategyKind};
use optcnn::util::json::Json;

/// The single-threaded reference: the plan JSON a fresh one-shot
/// `Planner` serves for (net, ndev, kind).
fn reference_plan_json(net: Network, ndev: usize, kind: StrategyKind) -> String {
    let mut p = Planner::builder(net).devices(ndev).build().unwrap();
    p.plan(kind).unwrap().to_json().to_string()
}

#[test]
fn concurrent_queries_match_one_shot_planner_bytes() {
    let combos: Vec<(Network, usize, StrategyKind)> = vec![
        (Network::LeNet5, 2, StrategyKind::Data),
        (Network::LeNet5, 2, StrategyKind::Layerwise),
        (Network::AlexNet, 4, StrategyKind::Owt),
        (Network::AlexNet, 4, StrategyKind::Layerwise),
    ];
    let reference: BTreeMap<usize, String> = combos
        .iter()
        .enumerate()
        .map(|(i, &(n, d, k))| (i, reference_plan_json(n, d, k)))
        .collect();

    let service = Arc::new(PlanService::new());
    let threads = 8;
    let rounds = 3;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let service = Arc::clone(&service);
            let combos = &combos;
            handles.push(scope.spawn(move || {
                let mut got = Vec::new();
                for r in 0..rounds {
                    for step in 0..combos.len() {
                        // rotate the visit order per (thread, round) so
                        // threads interleave on different combos
                        let i = (step + t + r) % combos.len();
                        let (n, d, k) = combos[i];
                        let req = PlanRequest::new(n, d).unwrap().strategy(k);
                        got.push((i, service.plan(&req).unwrap().to_json().to_string()));
                    }
                }
                got
            }));
        }
        for h in handles {
            for (i, json) in h.join().unwrap() {
                assert_eq!(
                    json, reference[&i],
                    "concurrently served plan diverged from the one-shot Planner (combo {i})"
                );
            }
        }
    });

    // every lookup is accounted for, and the working set stayed resident
    let stats = service.stats();
    assert_eq!(
        stats.plan_hits + stats.plan_misses,
        (threads * rounds * combos.len()) as u64
    );
    assert_eq!(stats.table_builds, 2, "one cost-table build per distinct (network, cluster)");
}

#[test]
fn single_flight_builds_tables_exactly_once() {
    let service = Arc::new(PlanService::new());
    let threads = 8;
    let barrier = Arc::new(Barrier::new(threads));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let service = Arc::clone(&service);
            let barrier = Arc::clone(&barrier);
            scope.spawn(move || {
                let req = PlanRequest::new(Network::LeNet5, 2)
                    .unwrap()
                    .strategy(StrategyKind::Layerwise);
                barrier.wait(); // all threads miss at the same instant
                service.evaluate(&req).unwrap();
            });
        }
    });
    let stats = service.stats();
    assert_eq!(
        stats.table_builds, 1,
        "duplicate concurrent misses must block on one build, not rebuild"
    );
    assert_eq!(stats.searches, 1, "the search ran once for all {threads} threads");
    assert_eq!(stats.plan_hits + stats.plan_misses, threads as u64);
    assert_eq!(stats.plan_misses, 1, "one plan key: first lookup builds, the rest hit");
}

#[test]
fn shard_counters_sum_coherently() {
    let service = Arc::new(PlanService::new());
    let combos = [
        (Network::LeNet5, 2, StrategyKind::Data),
        (Network::LeNet5, 2, StrategyKind::Model),
        (Network::LeNet5, 2, StrategyKind::Owt),
        (Network::AlexNet, 4, StrategyKind::Data),
        (Network::AlexNet, 4, StrategyKind::Model),
        (Network::AlexNet, 4, StrategyKind::Owt),
    ];
    let threads = 6;
    let rounds = 4;
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let service = Arc::clone(&service);
            let combos = &combos;
            scope.spawn(move || {
                for _ in 0..rounds {
                    for &(n, d, k) in combos.iter() {
                        let req = PlanRequest::new(n, d).unwrap().strategy(k);
                        service.plan(&req).unwrap();
                    }
                }
            });
        }
    });
    let total = (threads * rounds * combos.len()) as u64;
    let stats = service.stats();
    assert_eq!(stats.plan_hits + stats.plan_misses, total, "every lookup is a hit or a miss");
    assert_eq!(
        stats.plan_misses,
        combos.len() as u64,
        "each distinct plan built exactly once (shard mutex spans the build)"
    );
    assert_eq!(stats.plans_cached, combos.len());
    assert_eq!(stats.table_builds, 0, "baseline-only traffic builds no cost tables");
}

/// A five-layer chain whose middle conv varies in kernel/padding while
/// preserving shapes, so variants overlap on every other layer's memo key.
fn chain_variant(kernel: usize, pad: usize) -> NetworkSpec {
    let mut b = GraphBuilder::new(&format!("chain_k{kernel}"));
    let x = b.input(8, 3, 16, 16).unwrap();
    let c1 = b.conv2d("c1", x, 8, (3, 3), (1, 1), (1, 1)).unwrap();
    let c2 = b.conv2d("c2", c1, 8, (kernel, kernel), (1, 1), (pad, pad)).unwrap();
    let f = b.fully_connected("fc", c2, 10).unwrap();
    b.softmax("sm", f).unwrap();
    NetworkSpec::custom(b.finish().unwrap()).unwrap()
}

#[test]
fn memo_builds_each_distinct_layer_key_exactly_once_under_races() {
    // three graphs overlapping pairwise on 4 of 5 layers and 2 of 4
    // edges: 7 distinct layer keys + 8 distinct edge keys overall
    let graphs: Vec<NetworkSpec> =
        [(3usize, 1usize), (5, 2), (7, 3)].map(|(k, p)| chain_variant(k, p)).into();

    // a sequential service pins the ground truth: misses == distinct
    // keys, hits == shared-key reuse across the three builds
    let reference = PlanService::new();
    for g in &graphs {
        let req = PlanRequest::new(g.clone(), 2).unwrap().strategy(StrategyKind::Layerwise);
        reference.evaluate(&req).unwrap();
    }
    let expected = reference.stats();
    assert_eq!((expected.memo_misses, expected.memo_hits), (15, 12));

    // N threads hammer a second service with the same graphs in rotated
    // order, so overlapping layer keys race; a miss counts only a build
    // that actually ran, so equality with the sequential reference says
    // every distinct key was built exactly once despite the races
    let service = Arc::new(PlanService::new());
    let threads = 8;
    let barrier = Arc::new(Barrier::new(threads));
    std::thread::scope(|scope| {
        for t in 0..threads {
            let service = Arc::clone(&service);
            let barrier = Arc::clone(&barrier);
            let graphs = &graphs;
            scope.spawn(move || {
                barrier.wait();
                for step in 0..graphs.len() {
                    let g = graphs[(step + t) % graphs.len()].clone();
                    let req =
                        PlanRequest::new(g, 2).unwrap().strategy(StrategyKind::Layerwise);
                    service.evaluate(&req).unwrap();
                }
            });
        }
    });
    let stats = service.stats();
    assert_eq!(stats.table_builds, 3, "one whole-table build per distinct digest");
    assert_eq!(
        stats.memo_misses, expected.memo_misses,
        "racing builds must not rebuild a layer/edge key the memo already holds"
    );
    assert_eq!(
        stats.memo_hits, expected.memo_hits,
        "every shared key must be served from the memo, as in the sequential run"
    );
}

#[test]
fn serve_answers_over_a_real_socket() {
    let service = Arc::new(PlanService::new());
    let handle = serve::spawn("127.0.0.1:0", Arc::clone(&service)).unwrap();
    let addr = handle.local_addr();

    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut ask = |line: &str| -> Json {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        Json::parse(reply.trim_end()).unwrap()
    };

    // plan round-trip: byte-identical to the one-shot Planner plan
    let v = ask(r#"{"net": "lenet5", "devices": 2, "strategy": "data", "want": "plan"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(
        v.get("plan").unwrap().to_string(),
        reference_plan_json(Network::LeNet5, 2, StrategyKind::Data),
        "served plan must be byte-identical to the one-shot plan"
    );

    // evaluate round-trip on the same connection
    let v = ask(r#"{"net": "lenet5", "devices": 2, "strategy": "owt", "want": "evaluate"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    let eval = v.get("evaluation").unwrap();
    assert!(eval.get("throughput_img_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(eval.get("sim_step_s").unwrap().as_f64().unwrap() > 0.0);

    // an inline custom graph (the GraphSpec wire form) plans over the
    // same socket, and content-addresses to the builtin it mirrors
    let spec = optcnn::graph::nets::lenet5(64).unwrap().to_spec().to_string();
    let v = ask(&format!(r#"{{"graph": {spec}, "devices": 2, "want": "evaluate"}}"#));
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "inline graph must plan");
    let eval = v.get("evaluation").unwrap();
    assert!(eval.get("throughput_img_s").unwrap().as_f64().unwrap() > 0.0);

    // a malformed inline graph answers a typed one-line error
    let v = ask(
        r#"{"graph": {"version": 1, "name": "bad", "layers": [
            {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
            {"op": "fc", "cout": 10, "inputs": [9], "shape": [4, 10]}]}, "devices": 2}"#
            .replace('\n', " ")
            .as_str(),
    );
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    assert!(v.get("error").unwrap().as_str().unwrap().contains("invalid graph"));

    // a malformed request answers an error instead of dropping the line
    let v = ask(r#"{"net": "not-a-net", "devices": 2}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    assert!(v.get("error").unwrap().as_str().unwrap().contains("not-a-net"));

    // ... and the connection still works afterwards
    let v = ask(r#"{"net": "lenet5", "devices": 2, "strategy": "data", "want": "plan"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));

    // the metrics probe answers over the same socket with live numbers
    let v = ask(r#"{"want": "metrics"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    let m = v.get("metrics").unwrap();
    assert!(m.get("requests").unwrap().as_f64().unwrap() >= 6.0);
    assert!(m.get("p50_us").unwrap().as_f64().unwrap() >= 1.0);
    assert!(m.get("p99_us").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(m.get("shed").and_then(Json::as_f64), Some(0.0));
    assert_eq!(m.get("open_conns").and_then(Json::as_f64), Some(1.0));

    // the shared service actually served the traffic
    let stats = service.stats();
    assert!(stats.plan_hits + stats.plan_misses >= 3);

    // graceful shutdown with the client connection still open: the
    // registry unparks the worker, so this returns promptly
    handle.shutdown();
}

#[test]
fn overload_sheds_with_typed_reply_and_the_queue_drains() {
    use std::io::Read as _;

    let service = Arc::new(PlanService::new());
    let opts = serve::ServeOptions { workers: 1, queue_cap: 1, ..Default::default() };
    let handle = serve::spawn_opts("127.0.0.1:0", Arc::clone(&service), opts).unwrap();
    let addr = handle.local_addr();

    // conn 1 occupies the single worker — proved by its answered probe
    // (the worker is then parked reading this socket for the next line)
    let c1 = TcpStream::connect(addr).unwrap();
    let mut r1 = BufReader::new(c1.try_clone().unwrap());
    let mut w1 = c1;
    w1.write_all(b"{\"want\": \"stats\"}\n").unwrap();
    w1.flush().unwrap();
    let mut reply = String::new();
    r1.read_line(&mut reply).unwrap();
    assert_eq!(
        Json::parse(reply.trim_end()).unwrap().get("ok").and_then(Json::as_bool),
        Some(true)
    );

    // conn 2 takes the one queue slot (accepted in arrival order)
    let c2 = TcpStream::connect(addr).unwrap();

    // conn 3 finds the queue full: the accept loop sheds it with the
    // typed overload reply and closes — no unbounded queueing
    let c3 = TcpStream::connect(addr).unwrap();
    let mut r3 = BufReader::new(c3);
    let mut line = String::new();
    r3.read_line(&mut line).unwrap();
    let v = Json::parse(line.trim_end()).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "{line}");
    assert_eq!(v.get("error").and_then(Json::as_str), Some("overloaded"));
    assert_eq!(
        v.get("retry_after_ms").and_then(Json::as_f64),
        Some(serve::RETRY_AFTER_MS as f64)
    );
    let mut rest = Vec::new();
    r3.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "the shed connection is closed behind the reply");
    assert!(handle.metrics().shed.load(std::sync::atomic::Ordering::Relaxed) >= 1);

    // freeing the worker drains the queue: conn 2 is answered, not lost
    drop(w1);
    drop(r1);
    let mut r2 = BufReader::new(c2.try_clone().unwrap());
    let mut w2 = c2;
    w2.write_all(b"{\"net\": \"lenet5\", \"devices\": 2, \"strategy\": \"data\"}\n").unwrap();
    w2.flush().unwrap();
    let mut reply = String::new();
    r2.read_line(&mut reply).unwrap();
    let v = Json::parse(reply.trim_end()).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "queued connection drains");

    drop(w2);
    drop(r2);
    handle.shutdown();
}

#[test]
fn stalled_connections_are_closed_at_the_request_deadline() {
    let service = Arc::new(PlanService::new());
    let opts = serve::ServeOptions {
        request_timeout: std::time::Duration::from_millis(200),
        ..Default::default()
    };
    let handle = serve::spawn_opts("127.0.0.1:0", Arc::clone(&service), opts).unwrap();
    let c = TcpStream::connect(handle.local_addr()).unwrap();
    // never send a byte: the server must disconnect at the deadline
    // instead of parking a worker forever on a dead client
    let mut r = BufReader::new(c);
    let mut line = String::new();
    assert_eq!(r.read_line(&mut line).unwrap(), 0, "deadline closes the connection");
    handle.shutdown();
}
