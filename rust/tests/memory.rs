//! Properties of the per-device memory model and the
//! feasibility-constrained search (DESIGN.md §3):
//!
//! 1. a layer's per-device peak bytes are monotone non-increasing in
//!    every partition degree (checked exhaustively over nested config
//!    pairs of real networks);
//! 2. an infinite budget reproduces the unconstrained tables and plans
//!    byte-for-byte — masking is a no-op until a budget actually binds;
//! 3. a 16 GB P100 budget on vgg16@4 is satisfiable and the returned
//!    plan's recorded `peak_mem_per_dev` respects it;
//! 4. a genuinely tight budget shrinks the config space and every chosen
//!    configuration stays layer-feasible;
//! 5. an impossible budget is a typed `OptError::Infeasible`, never a
//!    panic or a silently wrong plan.

use optcnn::cost::{CostModel, CostTables};
use optcnn::device::DeviceGraph;
use optcnn::error::OptError;
use optcnn::graph::nets;
use optcnn::memory::{layer_peak_bytes, peak_per_device, MemBudget};
use optcnn::optimizer;
use optcnn::parallel::enumerate_configs;
use optcnn::planner::{Network, Planner, StrategyKind};

#[test]
fn peak_bytes_monotone_in_each_partition_degree() {
    // For every nested config pair (c1, c2) that differs in exactly one
    // dimension with c2's degree a proper multiple of c1's (so c2's
    // tiles subdivide c1's), the per-device peak must not grow: finer
    // partitioning can only shed parameter replicas and shrink the
    // resident activation window.
    for g in [nets::lenet5(64).unwrap(), nets::alexnet(128).unwrap()] {
        for l in &g.layers {
            let cfgs = enumerate_configs(l, 8);
            let peaks: Vec<f64> = cfgs.iter().map(|c| layer_peak_bytes(l, c)).collect();
            let mut pairs = 0usize;
            for (i, c1) in cfgs.iter().enumerate() {
                for (j, c2) in cfgs.iter().enumerate() {
                    let diff: Vec<usize> =
                        (0..4).filter(|&d| c1.deg[d] != c2.deg[d]).collect();
                    let &[d] = &diff[..] else { continue };
                    if c2.deg[d] > c1.deg[d] && c2.deg[d] % c1.deg[d] == 0 {
                        pairs += 1;
                        assert!(
                            peaks[j] <= peaks[i] * (1.0 + 1e-12),
                            "{}: raising {:?} to {:?} grew the peak {} -> {}",
                            l.name,
                            c1.deg,
                            c2.deg,
                            peaks[i],
                            peaks[j]
                        );
                    }
                }
            }
            assert!(
                cfgs.len() < 2 || pairs > 0,
                "{}: no nested pairs among {} configs",
                l.name,
                cfgs.len()
            );
        }
    }
}

#[test]
fn infinite_budget_reproduces_unconstrained_tables_exactly() {
    let g = nets::vgg16(64).unwrap();
    let d = DeviceGraph::p100_cluster(2).unwrap();
    let cm = CostModel::new(&g, &d);
    let free = CostTables::build(&cm, 2).unwrap();
    let inf = CostTables::build_budgeted(&cm, 2, Some(MemBudget::unlimited())).unwrap();
    assert_eq!(free.configs, inf.configs);
    assert_eq!(free.node_cost, inf.node_cost);
    assert_eq!(free.edges.len(), inf.edges.len());
    for (a, b) in free.edges.iter().zip(inf.edges.iter()) {
        assert_eq!((a.src, a.dst), (b.src, b.dst));
        assert_eq!(a.cost, b.cost);
    }
}

#[test]
fn infinite_budget_plans_are_byte_identical() {
    // The acceptance pin: with no (or a non-binding) budget, planning
    // output is byte-identical to the unconstrained path.
    let mut free = Planner::builder(Network::AlexNet).devices(4).build().unwrap();
    let mut capped = Planner::builder(Network::AlexNet)
        .devices(4)
        .mem_limit(u64::MAX)
        .build()
        .unwrap();
    let a = free.plan(StrategyKind::Layerwise).unwrap();
    let b = capped.plan(StrategyKind::Layerwise).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    let ea = free.evaluate(StrategyKind::Layerwise).unwrap();
    let eb = capped.evaluate(StrategyKind::Layerwise).unwrap();
    assert_eq!(ea.estimate, eb.estimate);
    assert_eq!(ea.sim.step_time, eb.sim.step_time);
    assert_eq!(ea.peak_mem_per_dev, eb.peak_mem_per_dev);
}

#[test]
fn p100_budget_on_vgg16_at_4_is_respected() {
    // The ISSUE's flagship scenario: vgg16 at 32/GPU on four 16 GB
    // P100s. The optimum must exist and the plan's recorded per-device
    // high water must fit the card.
    let budget = 16_000_000_000u64;
    let mut p = Planner::builder(Network::Vgg16)
        .devices(4)
        .mem_limit(budget)
        .build()
        .unwrap();
    assert_eq!(p.mem_limit(), Some(budget));
    let plan = p.plan(StrategyKind::Layerwise).unwrap();
    assert_eq!(plan.peak_mem_per_dev.len(), 4);
    assert!(
        plan.peak_mem() <= budget as f64,
        "recorded peak {} exceeds the 16 GB budget",
        plan.peak_mem()
    );
    // the recorded vector is the memory model's aggregation, not zeros
    assert!(plan.peak_mem_per_dev.iter().all(|&b| b > 0.0));
}

#[test]
fn tight_budget_masks_configs_and_the_optimum_stays_feasible() {
    // 2 GB per device on vgg16@4: serial early convs (~6.6 GB resident)
    // are masked out, but every layer keeps at least one config, so the
    // search still succeeds — over a strictly smaller space.
    let budget = 2_000_000_000.0f64;
    let g = nets::vgg16(32 * 4).unwrap();
    let d = DeviceGraph::p100_cluster(4).unwrap();
    let cm = CostModel::new(&g, &d);
    let free = CostTables::build(&cm, 4).unwrap();
    let tight =
        CostTables::build_budgeted(&cm, 4, Some(MemBudget { bytes_per_dev: budget }))
            .unwrap();
    let free_total: usize = (0..g.num_layers()).map(|l| free.num_configs(l)).sum();
    let tight_total: usize = (0..g.num_layers()).map(|l| tight.num_configs(l)).sum();
    assert!(
        tight_total < free_total,
        "a 2 GB budget must mask something ({free_total} vs {tight_total})"
    );
    let opt = optimizer::optimize(&tight);
    for (l, cfg) in opt.strategy.configs.iter().enumerate() {
        assert!(
            layer_peak_bytes(&g.layers[l], cfg) <= budget,
            "layer {} chose an over-budget config",
            g.layers[l].name
        );
    }
    // the recorded plan aggregation agrees with the memory model
    let plan = optcnn::plan::ExecutionPlan::build(&cm, &opt.strategy);
    assert_eq!(plan.peak_mem_per_dev, peak_per_device(&cm, &opt.strategy));
}

#[test]
fn impossible_budget_is_a_typed_infeasibility() {
    let mut p = Planner::builder(Network::Vgg16)
        .devices(4)
        .mem_limit(1_000_000) // 1 MB: no config of the stem fits
        .build()
        .unwrap();
    match p.evaluate(StrategyKind::Layerwise) {
        Err(OptError::Infeasible { layer, overshoot }) => {
            assert!(!layer.is_empty());
            assert!(overshoot > 0);
        }
        Err(other) => panic!("expected Infeasible, got {other}"),
        Ok(_) => panic!("a 1 MB budget cannot yield a plan"),
    }
}
