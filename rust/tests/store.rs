//! Integration suite for the content-addressed plan store (DESIGN.md
//! §13): round-trip byte identity, the warm-restart zero-build path,
//! rejection + eviction of truncated/corrupted/tampered entries behind
//! the verify gate, content-address authentication of misfiled entries,
//! and atomic-rename safety under concurrent writers.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Barrier};

use optcnn::device::DeviceGraph;
use optcnn::planner::{Network, PlanRequest, PlanService, StrategyKind};
use optcnn::store::{PlanStore, StoreKey};
use optcnn::util::json::Json;

/// A fresh per-(test, process) scratch directory. Tests remove it on
/// success; a failure leaves it behind for inspection.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("optcnn-store-test-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn service_with_store(dir: &Path) -> PlanService {
    PlanService::builder().plan_store(dir).build().unwrap()
}

/// The store key the service computes for a default LeNet-5 request at
/// 2 devices (per-GPU batch 32 -> global batch 64) with `strategy`.
fn lenet5_key(strategy: StrategyKind) -> StoreKey {
    let graph = optcnn::graph::nets::lenet5(64).unwrap();
    let devices = DeviceGraph::p100_cluster(2).unwrap();
    StoreKey::new(graph.digest(), &devices.fingerprint(), None, strategy.name(), false)
}

#[test]
fn round_trips_are_byte_identical() {
    let dir = scratch("roundtrip");
    let service = service_with_store(&dir);
    let req = PlanRequest::new(Network::LeNet5, 2).unwrap().strategy(StrategyKind::Data);
    let built = service.plan(&req).unwrap();

    let store = PlanStore::open(&dir).unwrap();
    let key = lenet5_key(StrategyKind::Data);
    assert!(store.contains(&key), "the service persisted under the documented content address");
    assert_eq!(store.len(), 1);
    let loaded = store.load(&key).unwrap().unwrap();
    assert_eq!(
        loaded.to_json().to_string(),
        built.to_json().to_string(),
        "a stored plan reads back byte-identical"
    );
    // absent keys are a clean miss, and eviction reports honestly
    let other = lenet5_key(StrategyKind::Owt);
    assert!(store.load(&other).unwrap().is_none());
    assert!(store.evict(&key));
    assert!(!store.evict(&key), "double eviction finds nothing");
    assert!(store.load(&key).unwrap().is_none());

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn warm_restart_serves_with_zero_table_builds() {
    let dir = scratch("warm-restart");
    let req = PlanRequest::new(Network::LeNet5, 2).unwrap();

    // cold service: builds tables, runs the search, persists the plan
    let cold = service_with_store(&dir);
    let built = cold.plan(&req).unwrap();
    let s = cold.stats();
    assert_eq!(s.table_builds, 1);
    assert_eq!(s.store_misses, 1, "the cold request checked disk before building");
    assert_eq!(s.store_writes, 1, "the fresh build was persisted");
    drop(cold);

    // "restarted" service on the same directory: the plan comes off
    // disk through the verify gate — no tables, no search
    let warm = service_with_store(&dir);
    let served = warm.plan(&req).unwrap();
    assert_eq!(
        served.to_json().to_string(),
        built.to_json().to_string(),
        "warm restart serves byte-identical bytes"
    );
    let s = warm.stats();
    assert_eq!(s.table_builds, 0, "warm restart must build nothing");
    assert_eq!(s.searches, 0);
    assert_eq!(s.store_hits, 1);
    assert_eq!(s.store_rejects, 0);

    // repeat traffic is answered by the in-memory tier: the disk is
    // not re-read, and still nothing is built
    let again = warm.plan(&req).unwrap();
    assert!(Arc::ptr_eq(&served, &again));
    let s = warm.stats();
    assert_eq!((s.table_builds, s.store_hits), (0, 1), "one disk read serves all warm repeats");

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn evaluate_also_rides_the_store_and_matches_the_cold_numbers() {
    let dir = scratch("warm-evaluate");
    let req = PlanRequest::new(Network::LeNet5, 2).unwrap();
    let cold = service_with_store(&dir);
    let reference = cold.evaluate(&req).unwrap();
    drop(cold);

    let warm = service_with_store(&dir);
    let eval = warm.evaluate(&req).unwrap();
    assert_eq!(eval.estimate, reference.estimate);
    assert_eq!(eval.sim.step_time, reference.sim.step_time);
    assert_eq!(eval.throughput, reference.throughput);
    assert_eq!(warm.stats().table_builds, 0, "evaluation of a stored plan builds nothing");

    let _ = fs::remove_dir_all(&dir);
}

/// Every way an entry can be bad on disk: unparsable, truncated, and
/// well-formed-but-tampered (which only the verify gate can catch). In
/// each case the service must reject, evict, rebuild correctly, and
/// re-persist — never serve the bad bytes, never retry them forever.
#[test]
fn bad_entries_are_rejected_evicted_and_rebuilt() {
    let key = lenet5_key(StrategyKind::Layerwise);
    let req = PlanRequest::new(Network::LeNet5, 2).unwrap();

    // the pristine reference entry, written once
    let dir = scratch("bad-entries");
    let reference = service_with_store(&dir).plan(&req).unwrap().to_json().to_string();
    let store = PlanStore::open(&dir).unwrap();
    let pristine = fs::read_to_string(store.path(&key)).unwrap();

    let corruptions: Vec<(&str, String)> = vec![
        ("garbage", "not json at all".to_string()),
        ("truncated", pristine[..pristine.len() / 2].to_string()),
        ("tampered", tamper_cost(&pristine)),
    ];
    for (what, bytes) in corruptions {
        fs::write(store.path(&key), bytes).unwrap();
        let service = service_with_store(&dir);
        let served = service.plan(&req).unwrap();
        assert_eq!(served.to_json().to_string(), reference, "{what}: rebuilt correctly");
        let s = service.stats();
        assert_eq!(s.store_rejects, 1, "{what}: the bad entry was rejected");
        assert_eq!(s.store_hits, 0, "{what}: a bad entry is never a hit");
        assert_eq!(s.table_builds, 1, "{what}: rejection falls back to a real build");
        // the rebuild re-persisted a pristine entry (eviction, not
        // permanent poisoning): the next restart is warm again
        assert_eq!(fs::read_to_string(store.path(&key)).unwrap(), pristine, "{what}");
        let healed = service_with_store(&dir);
        healed.plan(&req).unwrap();
        let s = healed.stats();
        assert_eq!((s.table_builds, s.store_hits), (0, 1), "{what}: healed store is warm");
    }

    let _ = fs::remove_dir_all(&dir);
}

/// Flip one bit of the plan's recorded cost inside an otherwise
/// well-formed envelope: the store's own decoding accepts it, so only
/// the `verify_plan` gate stands between it and a client.
fn tamper_cost(pristine: &str) -> String {
    let mut v = Json::parse(pristine).unwrap();
    let Json::Obj(envelope) = &mut v else { panic!("envelope must be an object") };
    let Some(Json::Obj(plan)) = envelope.get_mut("plan") else { panic!("plan must be an object") };
    let Some(Json::Num(cost)) = plan.get_mut("cost_s") else { panic!("cost_s must be a number") };
    *cost += 1.0;
    v.to_string()
}

#[test]
fn misfiled_entries_fail_the_content_address_check() {
    let dir = scratch("misfiled");
    let service = service_with_store(&dir);
    let data = PlanRequest::new(Network::LeNet5, 2).unwrap().strategy(StrategyKind::Data);
    service.plan(&data).unwrap();
    drop(service);

    // file the data-parallel entry under the OWT address: a hash
    // collision or an operator mixing up files looks exactly like this
    let store = PlanStore::open(&dir).unwrap();
    let data_key = lenet5_key(StrategyKind::Data);
    let owt_key = lenet5_key(StrategyKind::Owt);
    fs::copy(store.path(&data_key), store.path(&owt_key)).unwrap();

    // the embedded canonical key disagrees with the address: the load
    // is an eviction, and the service rebuilds the real OWT plan
    let owt = PlanRequest::new(Network::LeNet5, 2).unwrap().strategy(StrategyKind::Owt);
    let service = service_with_store(&dir);
    let plan = service.plan(&owt).unwrap();
    let expected = PlanService::new().plan(&owt).unwrap();
    assert_eq!(plan.to_json().to_string(), expected.to_json().to_string());
    assert_eq!(service.stats().store_rejects, 1);

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_writers_race_safely_through_atomic_renames() {
    let dir = scratch("writers");
    // one plan, built without a store, written by many racing threads
    let req = PlanRequest::new(Network::LeNet5, 2).unwrap().strategy(StrategyKind::Data);
    let plan = PlanService::new().plan(&req).unwrap();
    let key = lenet5_key(StrategyKind::Data);

    let store = Arc::new(PlanStore::open(&dir).unwrap());
    let threads = 8;
    let barrier = Arc::new(Barrier::new(threads));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let store = Arc::clone(&store);
            let barrier = Arc::clone(&barrier);
            let plan = Arc::clone(&plan);
            let key = key.clone();
            scope.spawn(move || {
                barrier.wait();
                store.save(&key, &plan).unwrap();
                // readers racing the writers see complete entries or
                // nothing — never a torn file
                if let Some(loaded) = store.load(&key).unwrap() {
                    assert_eq!(loaded.to_json().to_string(), plan.to_json().to_string());
                }
            });
        }
    });

    // exactly one entry, no leaked temp files, and it reads back clean
    assert_eq!(store.len(), 1);
    let leftovers = fs::read_dir(&dir).unwrap().count();
    assert_eq!(leftovers, 1, "no temp files survive the race");
    let loaded = store.load(&key).unwrap().unwrap();
    assert_eq!(loaded.to_json().to_string(), plan.to_json().to_string());
    assert!(!store.save_if_absent(&key, &plan).unwrap(), "present entries are not re-written");

    let _ = fs::remove_dir_all(&dir);
}
