//! Byte-identity suite for the parallel, memoized cost-table pipeline
//! (DESIGN.md §7): for every builtin network, at 2/4/8 devices, with and
//! without a per-device memory budget, the parallel + memoized build must
//! produce tables whose dimensions and contents are *bitwise* identical
//! to the serial build's, and the optimum searched over them must match.
//! `OPTCNN_BUILD_THREADS` overrides the parallel build's thread count so
//! CI can re-run the whole suite at a pinned width (default 0 = auto).

use optcnn::cost::{BuildOptions, CostModel, CostTables, TableMemo};
use optcnn::device::DeviceGraph;
use optcnn::graph::{nets, CompGraph, GraphBuilder};
use optcnn::memory::MemBudget;
use optcnn::optimizer;
use optcnn::planner::{Network, NetworkSpec, PlanRequest, PlanService, Planner, StrategyKind};

/// Thread count for the parallel side of each comparison: the
/// `OPTCNN_BUILD_THREADS` env var when set, else 0 (one worker per core).
fn par_threads() -> usize {
    std::env::var("OPTCNN_BUILD_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// Bitwise table equality: same config lists, same node-cost bits, same
/// edge endpoints, dimensions, and cost bits. `f64::to_bits` comparison
/// deliberately distinguishes -0.0/0.0 and NaN payloads — "identical"
/// means identical, not approximately equal.
fn assert_identical(a: &CostTables, b: &CostTables, tag: &str) {
    assert_eq!(a.configs, b.configs, "{tag}: per-layer config lists diverged");
    assert_eq!(a.node_cost.len(), b.node_cost.len(), "{tag}: layer count");
    for (l, (na, nb)) in a.node_cost.iter().zip(&b.node_cost).enumerate() {
        assert_eq!(na.len(), nb.len(), "{tag}: node table dims, layer {l}");
        for (i, (x, y)) in na.iter().zip(nb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: node_cost[{l}][{i}]");
        }
    }
    assert_eq!(a.edges.len(), b.edges.len(), "{tag}: edge count");
    for (e, (ea, eb)) in a.edges.iter().zip(&b.edges).enumerate() {
        assert_eq!((ea.src, ea.dst), (eb.src, eb.dst), "{tag}: edge {e} endpoints");
        assert_eq!(ea.cost.len(), eb.cost.len(), "{tag}: edge {e} dims");
        for (i, (x, y)) in ea.cost.iter().zip(&eb.cost).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: edge {e} cost[{i}]");
        }
    }
}

/// The full grid for one builtin: serial vs parallel-cold vs
/// parallel-warm (memoized) at every (ndev, budget) combination, plus
/// optimum identity over the resulting tables.
fn builtin_identity(net: &str) {
    let threads = par_threads();
    for ndev in [2usize, 4, 8] {
        let g = nets::by_name(net, 32 * ndev).unwrap();
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&g, &d);
        for budget in [None, Some(MemBudget::new(16_000_000_000))] {
            let tag = format!(
                "{net}@{ndev}dev budget={}",
                budget.map_or("none".to_string(), |b| format!("{}", b.bytes_per_dev))
            );
            let serial = BuildOptions { threads: 1, memo: None };
            let reference = CostTables::build_opts(&cm, ndev, budget, &serial)
                .unwrap_or_else(|e| panic!("{tag}: serial build failed: {e}"));
            let memo = TableMemo::new();
            let opts = BuildOptions { threads, memo: Some(&memo) };
            let cold = CostTables::build_opts(&cm, ndev, budget, &opts).unwrap();
            assert_identical(&reference, &cold, &format!("{tag} [cold]"));
            let before = memo.stats();
            assert!(before.misses > 0, "{tag}: the cold build must populate the memo");
            let warm = CostTables::build_opts(&cm, ndev, budget, &opts).unwrap();
            assert_identical(&reference, &warm, &format!("{tag} [warm]"));
            let after = memo.stats();
            assert_eq!(after.misses, before.misses, "{tag}: warm rebuild must not rebuild");
            assert!(after.hits > before.hits, "{tag}: warm rebuild must hit the memo");
            let (a, b) = (optimizer::optimize(&reference), optimizer::optimize(&cold));
            assert_eq!(a.strategy, b.strategy, "{tag}: optimal strategy diverged");
            assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{tag}: optimal cost diverged");
        }
    }
}

#[test]
fn identity_lenet5() {
    builtin_identity("lenet5");
}

#[test]
fn identity_alexnet() {
    builtin_identity("alexnet");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "heavy grid; the release CI steps run it")]
fn identity_vgg16() {
    builtin_identity("vgg16");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "heavy grid; the release CI steps run it")]
fn identity_inception_v3() {
    builtin_identity("inception_v3");
}

#[test]
fn identity_resnet18() {
    builtin_identity("resnet18");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "heavy grid; the release CI steps run it")]
fn identity_resnet50() {
    builtin_identity("resnet50");
}

#[test]
fn identity_minicnn() {
    builtin_identity("minicnn");
}

/// End-to-end determinism: the exported plan JSON off a `Planner` session
/// must not depend on `--build-threads`.
#[test]
fn plan_json_is_identical_across_thread_counts() {
    for net in [Network::LeNet5, Network::AlexNet, Network::MiniCnn] {
        let serial = {
            let mut p =
                Planner::builder(net).devices(4).build_threads(1).build().unwrap();
            p.plan(StrategyKind::Layerwise).unwrap().to_json().to_string()
        };
        let parallel = {
            let mut p =
                Planner::builder(net).devices(4).build_threads(4).build().unwrap();
            p.plan(StrategyKind::Layerwise).unwrap().to_json().to_string()
        };
        assert_eq!(serial, parallel, "{net}: plan JSON depends on --build-threads");
    }
}

/// A five-layer chain whose middle conv varies in kernel/padding while
/// preserving its output shape, so every *other* layer's canonical form —
/// and therefore its memo key — is unchanged between the two variants.
fn chain_graph(name: &str, kernel: usize, pad: usize) -> CompGraph {
    let mut b = GraphBuilder::new(name);
    let x = b.input(8, 3, 16, 16).unwrap();
    let c1 = b.conv2d("c1", x, 8, (3, 3), (1, 1), (1, 1)).unwrap();
    let c2 = b.conv2d("c2", c1, 8, (kernel, kernel), (1, 1), (pad, pad)).unwrap();
    let f = b.fully_connected("fc", c2, 10).unwrap();
    b.softmax("sm", f).unwrap();
    b.finish().unwrap()
}

/// Content-addressed sharing across graphs: planning a second graph that
/// differs from the first in exactly one layer rebuilds only that layer's
/// node table and its two incident edge tables — everything else is a
/// per-layer memo hit, even though the graphs' digests (and so their
/// whole-table cache entries) differ.
#[test]
fn shared_layers_hit_the_memo_across_graphs() {
    let service = PlanService::new();
    let a = NetworkSpec::custom(chain_graph("chain_a", 3, 1)).unwrap();
    let b = NetworkSpec::custom(chain_graph("chain_b", 5, 2)).unwrap();

    let req = PlanRequest::new(a, 2).unwrap().strategy(StrategyKind::Layerwise);
    service.evaluate(&req).unwrap();
    let cold = service.stats();
    assert_eq!(cold.table_builds, 1);
    // 5 distinct layers + 4 distinct edges, no intra-graph aliasing
    assert_eq!((cold.memo_misses, cold.memo_hits), (9, 0));

    let req = PlanRequest::new(b, 2).unwrap().strategy(StrategyKind::Layerwise);
    service.evaluate(&req).unwrap();
    let warm = service.stats();
    assert_eq!(warm.table_builds, 2, "distinct digests must each build a table");
    assert_eq!(
        warm.memo_misses - cold.memo_misses,
        3,
        "only the changed conv and its two incident edges rebuild"
    );
    assert_eq!(
        warm.memo_hits - cold.memo_hits,
        6,
        "the 4 unchanged layers and 2 untouched edges must hit the memo"
    );
}
