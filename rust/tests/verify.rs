//! Mutation corpus for the static plan verifier (DESIGN.md §10).
//!
//! Each test starts from a plan `ExecutionPlan::build` actually produced,
//! corrupts exactly one invariant, and asserts the verifier rejects it
//! with the *named* check — not a panic, not a neighbouring check. The
//! clean grid at the bottom proves the converse: every builtin network,
//! at several cluster sizes, verifies clean straight out of the builder.

use optcnn::cost::CostModel;
use optcnn::device::DeviceGraph;
use optcnn::graph::{nets, CompGraph};
use optcnn::optimizer::strategies;
use optcnn::plan::ExecutionPlan;
use optcnn::verify::verify_plan;
use optcnn::{OptError, PlanCheck};

/// Build a `(graph, devices, plan)` triple for a builtin network under a
/// baseline strategy, with a per-GPU batch of 32.
fn setup(net: &str, ndev: usize, strat: &str) -> (CompGraph, DeviceGraph, ExecutionPlan) {
    let g = nets::by_name(net, 32 * ndev).unwrap();
    let d = DeviceGraph::p100_cluster(ndev).unwrap();
    let s = strategies::by_name(strat, &g, ndev).unwrap();
    let plan = ExecutionPlan::build(&CostModel::new(&g, &d), &s);
    (g, d, plan)
}

/// Run the verifier and unwrap the expected structured rejection.
fn reject(g: &CompGraph, d: &DeviceGraph, plan: &ExecutionPlan) -> OptError {
    let cm = CostModel::new(g, d);
    match verify_plan(&cm, plan) {
        Err(e) => e,
        Ok(report) => panic!("mutant verified clean:\n{report}"),
    }
}

/// Assert the error names `want` (and nothing else) and mentions
/// `needle` in its diagnostic.
fn assert_check(err: &OptError, want: PlanCheck, needle: &str) {
    match err {
        OptError::InvalidPlan { check, detail } => {
            assert_eq!(*check, want, "wrong check named: {err}");
            assert!(detail.contains(needle), "diagnostic {detail:?} lacks {needle:?}");
        }
        other => panic!("expected InvalidPlan, got {other}"),
    }
}

#[test]
fn overlapping_tiles_fail_tile_coverage() {
    let (g, d, mut plan) = setup("lenet5", 2, "data");
    // Data parallelism splits every layer on dim 0: widening tile 0's
    // sample range makes it overlap tile 1.
    let lp = &mut plan.layers[0];
    let end = lp.tiles[0].end(0);
    lp.tiles[0].set(0, 0, end + 1);
    let err = reject(&g, &d, &plan);
    assert_check(&err, PlanCheck::TileCoverage, "overlaps");
}

#[test]
fn out_of_range_tile_device_fails_tile_coverage() {
    let (g, d, mut plan) = setup("lenet5", 2, "data");
    let ndev = plan.ndev;
    plan.layers[1].tile_dev[0] = ndev;
    let err = reject(&g, &d, &plan);
    assert_check(&err, PlanCheck::TileCoverage, "placed on device");
}

#[test]
fn misplaced_tile_fails_tile_coverage() {
    let (g, d, mut plan) = setup("lenet5", 2, "data");
    // In-range but disagreeing with the shared placement function.
    plan.layers[1].tile_dev.swap(0, 1);
    let err = reject(&g, &d, &plan);
    assert_check(&err, PlanCheck::TileCoverage, "placement assigns");
}

#[test]
fn dropped_transfer_fails_transfer_completeness() {
    let (g, d, mut plan) = setup("alexnet", 4, "owt");
    let ep = plan
        .edges
        .iter_mut()
        .find(|e| !e.transfers.is_empty())
        .expect("owt plan moves data on some edge");
    ep.transfers.pop();
    let err = reject(&g, &d, &plan);
    assert_check(&err, PlanCheck::TransferCompleteness, "is not covered");
}

#[test]
fn out_of_range_transfer_device_fails_transfer_completeness() {
    let (g, d, mut plan) = setup("alexnet", 4, "owt");
    let ndev = plan.ndev;
    let ep = plan
        .edges
        .iter_mut()
        .find(|e| !e.transfers.is_empty())
        .expect("owt plan moves data on some edge");
    ep.transfers[0].dst_dev = ndev;
    let err = reject(&g, &d, &plan);
    assert_check(&err, PlanCheck::TransferCompleteness, "placement shape");
}

#[test]
fn stale_shard_bytes_fails_sync_groups() {
    let (g, d, mut plan) = setup("lenet5", 2, "data");
    let sync = plan
        .layers
        .iter_mut()
        .find_map(|lp| lp.sync.as_mut())
        .expect("data parallelism replicates parameters somewhere");
    sync.shard_bytes += 1.0;
    let err = reject(&g, &d, &plan);
    assert_check(&err, PlanCheck::SyncGroups, "sharding implies");
}

#[test]
fn dropped_sync_group_fails_sync_groups() {
    let (g, d, mut plan) = setup("lenet5", 2, "data");
    let lp = plan
        .layers
        .iter_mut()
        .find(|lp| lp.sync.is_some())
        .expect("data parallelism replicates parameters somewhere");
    lp.sync = None;
    let err = reject(&g, &d, &plan);
    assert_check(&err, PlanCheck::SyncGroups, "carries no sync plan");
}

#[test]
fn inflated_peak_memory_fails_memory_consistency() {
    let (g, d, mut plan) = setup("lenet5", 2, "data");
    plan.peak_mem_per_dev[0] += 1.0;
    let err = reject(&g, &d, &plan);
    assert_check(&err, PlanCheck::MemoryConsistency, "memory model derives");
}

#[test]
fn stale_cost_fails_cost_coherence() {
    let (g, d, mut plan) = setup("lenet5", 2, "data");
    plan.cost_s *= 2.0;
    let err = reject(&g, &d, &plan);
    assert_check(&err, PlanCheck::CostCoherence, "cost model derives");
}

#[test]
fn mutations_survive_a_json_round_trip() {
    // A corrupt plan must be rejected whether it was mutated in memory
    // or arrived as a (well-formed) JSON document.
    use optcnn::util::json::Json;
    let (g, d, mut plan) = setup("lenet5", 2, "data");
    plan.cost_s += 0.5;
    let doc = Json::parse(&plan.to_json().to_string()).unwrap();
    let back = ExecutionPlan::from_json(&doc).unwrap();
    let err = reject(&g, &d, &back);
    assert_check(&err, PlanCheck::CostCoherence, "cost model derives");
}

#[test]
fn all_builtin_networks_verify_clean_at_every_cluster_size() {
    for net in ["lenet5", "alexnet", "vgg16", "inception_v3", "resnet18", "resnet50", "minicnn"] {
        for ndev in [2usize, 4, 8] {
            for strat in ["data", "owt"] {
                let (g, d, plan) = setup(net, ndev, strat);
                let cm = CostModel::new(&g, &d);
                let report = verify_plan(&cm, &plan)
                    .unwrap_or_else(|e| panic!("{net}@{ndev}/{strat}: {e}"));
                assert_eq!(report.checks.len(), PlanCheck::ALL.len(), "{net}@{ndev}/{strat}");
            }
        }
    }
}
