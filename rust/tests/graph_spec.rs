//! Graph-ingestion acceptance suite: every builtin network survives the
//! builder -> GraphSpec JSON -> CompGraph round trip **byte-identically**
//! (same optimal step time, same plan JSON) at 2 and 4 devices; malformed
//! specs are typed `InvalidGraph` rejections; and structurally identical
//! specs content-address to one PlanService cache entry no matter how
//! they were spelled.

use std::sync::Arc;

use optcnn::error::OptError;
use optcnn::graph::CompGraph;
use optcnn::planner::{Network, NetworkSpec, PlanRequest, PlanService, Planner, StrategyKind};
use optcnn::util::json::Json;

/// Round-trip a builder-built graph through its spec text.
fn reload(g: &CompGraph) -> CompGraph {
    let text = g.to_spec().to_string();
    CompGraph::from_spec(&Json::parse(&text).expect("spec text parses")).expect("spec validates")
}

#[test]
fn every_builtin_plans_byte_identically_from_its_spec() {
    for net in Network::ALL {
        for ndev in [2usize, 4] {
            let mut direct = Planner::builder(net).devices(ndev).build().unwrap();
            let spec = NetworkSpec::custom(reload(direct.graph())).unwrap();
            let mut loaded = Planner::builder(spec).devices(ndev).build().unwrap();
            assert_eq!(direct.global_batch(), loaded.global_batch(), "{net}@{ndev}");

            // identical optimal step time from the layer-wise search
            let a = direct.optimize().unwrap();
            let b = loaded.optimize().unwrap();
            assert_eq!(a.cost, b.cost, "{net}@{ndev}: optimal cost must match exactly");
            assert_eq!(a.strategy, b.strategy, "{net}@{ndev}: optimal strategy must match");

            // identical materialized plan bytes
            let pa = direct.plan(StrategyKind::Layerwise).unwrap();
            let pb = loaded.plan(StrategyKind::Layerwise).unwrap();
            assert_eq!(
                pa.to_json().to_string(),
                pb.to_json().to_string(),
                "{net}@{ndev}: plan JSON must be byte-identical"
            );
        }
    }
}

#[test]
fn malformed_spec_corpus_returns_invalid_graph() {
    let corpus: &[(&str, &str)] = &[
        (
            "dangling edge",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "fc", "cout": 10, "inputs": [7], "shape": [4, 10]}]}"#,
        ),
        (
            "cycle (backward input)",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "conv", "cout": 3, "kernel": [1, 1], "stride": [1, 1],
                 "padding": [0, 0], "inputs": [2], "shape": [4, 3, 8, 8]},
                {"op": "conv", "cout": 3, "kernel": [1, 1], "stride": [1, 1],
                 "padding": [0, 0], "inputs": [1], "shape": [4, 3, 8, 8]}]}"#,
        ),
        (
            "self-loop",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "add", "inputs": [1, 1], "shape": [4, 3, 8, 8]}]}"#,
        ),
        (
            "shape mismatch",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "conv", "cout": 16, "kernel": [3, 3], "stride": [1, 1],
                 "padding": [1, 1], "inputs": [0], "shape": [4, 99, 8, 8]}]}"#,
        ),
        (
            "oversized kernel",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "conv", "cout": 4, "kernel": [64, 64], "stride": [1, 1],
                 "padding": [0, 0], "inputs": [0], "shape": [4, 4, 1, 1]}]}"#,
        ),
        (
            "zero stride",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "pool", "kind": "max", "kernel": [2, 2], "stride": [0, 2],
                 "padding": [0, 0], "inputs": [0], "shape": [4, 3, 4, 4]}]}"#,
        ),
        (
            "zero-extent input",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [0, 3, 8, 8]}]}"#,
        ),
        (
            "second input layer",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]}]}"#,
        ),
        (
            "wrong arity add",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "add", "inputs": [0], "shape": [4, 3, 8, 8]}]}"#,
        ),
        (
            "unknown op",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "warp", "inputs": [0], "shape": [4, 3, 8, 8]}]}"#,
        ),
        (
            "billion-sample batch (extent cap)",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [1000000000000, 3, 4, 4]}]}"#,
        ),
        (
            "oversized layer volume",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [65536, 65536, 65536, 4]}]}"#,
        ),
        (
            "overflowing padding (window cap)",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "conv", "cout": 4, "kernel": [3, 3], "stride": [1, 1],
                 "padding": [999999999, 1], "inputs": [0], "shape": [4, 4, 8, 8]}]}"#,
        ),
        (
            "duplicate inputs",
            r#"{"version": 1, "name": "bad", "layers": [
                {"op": "input", "inputs": [], "shape": [4, 3, 8, 8]},
                {"op": "conv", "cout": 4, "kernel": [1, 1], "stride": [1, 1],
                 "padding": [0, 0], "inputs": [0], "shape": [4, 4, 8, 8]},
                {"op": "concat", "inputs": [1, 1], "shape": [4, 8, 8, 8]}]}"#,
        ),
    ];
    for (what, text) in corpus {
        let err = CompGraph::from_spec(&Json::parse(text).unwrap()).unwrap_err();
        assert!(
            matches!(err, OptError::InvalidGraph(_)),
            "{what}: expected InvalidGraph, got {err:?}"
        );
        let msg = err.to_string();
        assert!(!msg.is_empty() && !msg.contains('\n'), "{what}: {msg:?}");
        assert_eq!(err.exit_code(), 2, "{what}: malformed specs are usage errors");
    }
}

#[test]
fn textually_different_specs_share_one_service_cache_entry() {
    // The same network spelled three ways: builder export, reordered/
    // reformatted JSON (BTreeMap canonicalizes on parse anyway, so vary
    // what actually can vary: layer names and the graph name's spelling
    // stays — names are cosmetic and excluded from the digest).
    let base = optcnn::graph::nets::minicnn(64).unwrap();
    let text_a = base.to_spec().to_string();
    let text_b = {
        // rename every layer and inject whitespace: textually different,
        // structurally identical
        let renamed = text_a.replace(r#""name":"conv1""#, r#""name":"first_conv""#);
        renamed.replace(":", " : ").replace(",", " , ")
    };
    assert_ne!(text_a, text_b);
    let ga = CompGraph::from_spec(&Json::parse(&text_a).unwrap()).unwrap();
    let gb = CompGraph::from_spec(&Json::parse(&text_b).unwrap()).unwrap();
    assert_eq!(ga.digest(), gb.digest(), "cosmetic differences must not change identity");

    let service = PlanService::new();
    let req_a = PlanRequest::new(NetworkSpec::custom(ga).unwrap(), 2).unwrap();
    let req_b = PlanRequest::new(NetworkSpec::custom(gb).unwrap(), 2).unwrap();
    let plan_a = service.plan(&req_a).unwrap();
    let plan_b = service.plan(&req_b).unwrap();
    assert!(
        Arc::ptr_eq(&plan_a, &plan_b),
        "structurally identical specs must hit the same cache entry"
    );
    let stats = service.stats();
    assert_eq!(stats.table_builds, 1, "one single-flight build for one digest");
    assert_eq!((stats.plan_hits, stats.plan_misses), (1, 1));

    // ... and a structurally different batch is a different address
    let other = optcnn::graph::nets::minicnn(128).unwrap();
    let req_c = PlanRequest::new(NetworkSpec::custom(other).unwrap(), 2).unwrap();
    let plan_c = service.plan(&req_c).unwrap();
    assert!(!Arc::ptr_eq(&plan_a, &plan_c), "distinct graphs must never alias");
    assert_eq!(service.stats().table_builds, 2);
}

#[test]
fn custom_and_preset_share_state_when_structurally_equal() {
    // A spec exported from a builtin IS that builtin to the service: the
    // preset path and the custom path converge on one digest.
    let service = PlanService::new();
    let preset = PlanRequest::new(Network::LeNet5, 2).unwrap();
    let plan_preset = service.plan(&preset).unwrap();
    let spec = NetworkSpec::custom(reload(&optcnn::graph::nets::lenet5(64).unwrap())).unwrap();
    let custom = PlanRequest::new(spec, 2).unwrap();
    let plan_custom = service.plan(&custom).unwrap();
    assert!(Arc::ptr_eq(&plan_preset, &plan_custom));
    assert_eq!(service.stats().table_builds, 1);
}

#[test]
fn checked_in_minicnn_spec_is_the_builtin() {
    // the spec shipped under config/ must load, validate, and be
    // structurally identical to `nets::minicnn(64)` — `optcnn graph
    // --validate` runs over it in CI
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../config/minicnn.graph.json");
    let text = std::fs::read_to_string(path).unwrap();
    let g = CompGraph::from_spec(&Json::parse(&text).unwrap()).unwrap();
    let builtin = optcnn::graph::nets::minicnn(64).unwrap();
    assert_eq!(g.digest(), builtin.digest());
    assert_eq!(g.name, "minicnn");
}

#[test]
fn evaluations_agree_between_spec_and_builder_paths() {
    // beyond plan bytes: the derived numbers (estimate, simulated step,
    // comm) agree exactly for a mid-size branchy net
    let mut direct = Planner::builder(Network::ResNet18).devices(2).build().unwrap();
    let spec = NetworkSpec::custom(reload(direct.graph())).unwrap();
    let mut loaded = Planner::builder(spec).devices(2).build().unwrap();
    for kind in [StrategyKind::Data, StrategyKind::Owt] {
        let a = direct.evaluate(kind).unwrap();
        let b = loaded.evaluate(kind).unwrap();
        assert_eq!(a.estimate, b.estimate, "{kind}");
        assert_eq!(a.sim.step_time, b.sim.step_time, "{kind}");
        assert_eq!(a.comm.total(), b.comm.total(), "{kind}");
        assert_eq!(a.peak_mem_per_dev, b.peak_mem_per_dev, "{kind}");
    }
}
