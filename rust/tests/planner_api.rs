//! Integration tests for the typed `Planner` session API: name
//! round-trips, builder validation, backend pluggability, and the
//! session-amortization contract (a warm session answers repeated
//! queries without rebuilding cost tables, and its plans are
//! byte-identical to the one-shot path).

use std::sync::Arc;

use optcnn::device::DeviceGraph;
use optcnn::error::OptError;
use optcnn::planner::{ClusterSpec, ExhaustiveDfs, Network, Planner, StrategyKind};

#[test]
fn network_names_round_trip() {
    for net in Network::ALL {
        let parsed: Network = net.name().parse().unwrap();
        assert_eq!(parsed, net);
        assert_eq!(format!("{net}"), net.name());
    }
    // historical aliases resolve too
    assert_eq!("vgg".parse::<Network>().unwrap(), Network::Vgg16);
    assert_eq!("inception".parse::<Network>().unwrap(), Network::InceptionV3);
    let err = "resnet1001".parse::<Network>().unwrap_err();
    assert!(err.to_string().contains("resnet1001"), "{err}");
    assert_eq!(err.exit_code(), 2);
}

#[test]
fn strategy_names_round_trip() {
    for kind in StrategyKind::ALL {
        let parsed: StrategyKind = kind.name().parse().unwrap();
        assert_eq!(parsed, kind);
        assert_eq!(format!("{kind}"), kind.name());
    }
    assert!(matches!("zigzag".parse::<StrategyKind>(), Err(OptError::UnknownStrategy(_))));
}

#[test]
fn builder_rejects_bad_configurations() {
    // zero batch
    assert!(matches!(
        Planner::builder(Network::LeNet5).devices(2).per_gpu_batch(0).build(),
        Err(OptError::InvalidArgument(_))
    ));
    // a device count the P100 preset cannot shape
    assert!(matches!(
        Planner::builder(Network::LeNet5).devices(7).build(),
        Err(OptError::InvalidCluster(_))
    ));
    // ambiguous cluster selection
    assert!(Planner::builder(Network::LeNet5)
        .devices(2)
        .cluster(ClusterSpec::new(1, 2))
        .build()
        .is_err());
    // degenerate cluster specs surface at build, not as NaNs later
    assert!(Planner::builder(Network::LeNet5)
        .cluster(ClusterSpec::new(0, 4))
        .build()
        .is_err());
    assert!(Planner::builder(Network::LeNet5)
        .cluster(ClusterSpec::new(1, 2).inter_bw(0.0))
        .build()
        .is_err());
}

#[test]
fn device_graph_validation() {
    use optcnn::device::ComputeModel;
    assert!(DeviceGraph::cluster("x", 0, 1, 1e9, 1e9, 1e9, ComputeModel::p100()).is_err());
    assert!(DeviceGraph::cluster("x", 1, 1, -1.0, 1e9, 1e9, ComputeModel::p100()).is_err());
    assert!(DeviceGraph::p100_cluster(0).is_err());
    assert!(DeviceGraph::p100_cluster(6).is_err());
    let d = DeviceGraph::cluster("x", 2, 2, 2e9, 1e9, 1e9, ComputeModel::v100()).unwrap();
    assert_eq!(d.num_devices(), 4);
    assert!(d.transfer_time(0, 3, 1e9).is_finite());
}

/// The acceptance contract: a warm `Planner` answers a repeated
/// vgg16/4-device `layerwise` query without rebuilding `CostTables`, and
/// the plan it serves is byte-identical to a fresh one-shot session's.
#[test]
fn warm_session_reuses_tables_and_serves_identical_plans() {
    let mut session = Planner::builder(Network::Vgg16).devices(4).build().unwrap();
    let cold = session.plan(StrategyKind::Layerwise).unwrap();
    let after_cold = session.session_stats();
    assert_eq!(after_cold.table_builds, 1);
    assert_eq!(after_cold.searches, 1);
    assert_eq!(after_cold.plan_misses, 1);

    // warm repeat: no new tables, no new search, plan served from cache
    let warm = session.plan(StrategyKind::Layerwise).unwrap();
    let after_warm = session.session_stats();
    assert_eq!(after_warm.table_builds, 1, "warm query must not rebuild CostTables");
    assert_eq!(after_warm.searches, 1, "warm query must not re-run the search");
    assert_eq!(after_warm.plan_hits, 1);
    assert!(Arc::ptr_eq(&cold, &warm), "warm plan must be the cached object");

    // byte-identical to the one-shot path
    let one_shot = Planner::builder(Network::Vgg16)
        .devices(4)
        .build()
        .unwrap()
        .plan(StrategyKind::Layerwise)
        .unwrap();
    assert_eq!(
        warm.to_json().to_string(),
        one_shot.to_json().to_string(),
        "session-served plan must be byte-identical to the one-shot plan"
    );

    // and the evaluations derived from it agree exactly
    let a = session.evaluate(StrategyKind::Layerwise).unwrap();
    let b = session.evaluate(StrategyKind::Layerwise).unwrap();
    assert_eq!(a.estimate, b.estimate);
    assert_eq!(a.sim.step_time, b.sim.step_time);
    assert_eq!(a.comm.total(), b.comm.total());
}

#[test]
fn dfs_backend_matches_elimination_on_small_nets() {
    let mut elim = Planner::builder(Network::LeNet5).devices(2).build().unwrap();
    let mut dfs = Planner::builder(Network::LeNet5)
        .devices(2)
        .backend(ExhaustiveDfs::default())
        .build()
        .unwrap();
    assert_eq!(dfs.backend_name(), "dfs");
    let a = elim.optimize().unwrap();
    let b = dfs.optimize().unwrap();
    assert!(
        (a.cost - b.cost).abs() <= 1e-9 * a.cost,
        "backends disagree: elimination {} vs dfs {}",
        a.cost,
        b.cost
    );
}

#[test]
fn arbitrary_clusters_are_first_class() {
    // same device count, different fabric: the planner must produce a
    // valid (and generally different-cost) answer on both
    let nvlink = ClusterSpec::new(1, 4).name("nvlink-box");
    let pcie = ClusterSpec::new(1, 4).name("pcie-box").intra_bw(4e9).host_bw(4e9);
    let mut fast = Planner::builder(Network::AlexNet).cluster(nvlink).build().unwrap();
    let mut slow = Planner::builder(Network::AlexNet).cluster(pcie).build().unwrap();
    let f = fast.evaluate(StrategyKind::Layerwise).unwrap();
    let s = slow.evaluate(StrategyKind::Layerwise).unwrap();
    assert!(f.estimate > 0.0 && s.estimate > 0.0);
    assert!(
        s.estimate >= f.estimate * (1.0 - 1e-9),
        "slower fabric cannot make the optimum faster: {} vs {}",
        s.estimate,
        f.estimate
    );
}

#[test]
fn per_gpu_batch_flows_into_the_graph() {
    let p = Planner::builder(Network::LeNet5).devices(2).per_gpu_batch(16).build().unwrap();
    assert_eq!(p.global_batch(), 32);
    assert_eq!(p.graph().layers[0].out_shape[0], 32);
}
