//! Helpers shared across the integration-test binaries. Every test
//! target that declares `mod common;` compiles this file independently,
//! so a helper unused by one target is expected dead code there.
#![allow(dead_code)]

use optcnn::device::DeviceGraph;
use optcnn::graph::{CompGraph, GraphBuilder};
use optcnn::prop::Gen;

pub fn p100(n: usize) -> DeviceGraph {
    DeviceGraph::p100_cluster(n).unwrap()
}

/// A random series-parallel CNN: a chain of segments, each either a
/// single conv or a two-branch diamond re-joined by add/concat. Every
/// such graph must collapse under node+edge elimination (the diamond's
/// branches are (1,1)-degree nodes; the parallel edges they leave merge).
/// Odd extents (channels 3, spatial 5) keep per-layer config counts at
/// 2-3 for ndev=2, so exhaustive searches over these graphs stay small.
pub fn random_series_parallel(g: &mut Gen) -> CompGraph {
    let mut b = GraphBuilder::new("sp");
    let mut cur = b.input(2, 3, 5, 5).unwrap();
    let segs = g.usize_in(1, 5);
    for i in 0..segs {
        if g.bool() {
            let l = b.conv2d(&format!("dl{i}"), cur, 3, (3, 3), (1, 1), (1, 1)).unwrap();
            let r = b.conv2d(&format!("dr{i}"), cur, 3, (1, 1), (1, 1), (0, 0)).unwrap();
            cur = if g.bool() {
                b.add(&format!("j{i}"), l, r).unwrap()
            } else {
                b.concat(&format!("j{i}"), &[l, r]).unwrap()
            };
        } else {
            cur = b.conv2d(&format!("c{i}"), cur, 3, (3, 3), (1, 1), (1, 1)).unwrap();
        }
    }
    let f = b.fully_connected("fc", cur, 10).unwrap();
    b.softmax("sm", f).unwrap();
    b.finish().unwrap()
}
