//! Cross-subsystem consistency of the materialized `ExecutionPlan` IR:
//! the cost model's byte accounting, the discrete-event simulator, and
//! the executor's planned communication must all agree because they now
//! consume (or mirror) the same plan — plus exact JSON round-trips, the
//! acceptance contract for plans as servable artifacts.

use optcnn::cost::CostModel;
use optcnn::device::DeviceGraph;
use optcnn::exec::CommStats;
use optcnn::graph::{nets, GraphBuilder, PoolKind};
use optcnn::metrics::comm_volume;
use optcnn::optimizer::strategies;
use optcnn::plan::ExecutionPlan;
use optcnn::prop::{forall, Gen};
use optcnn::sim::{simulate, simulate_plan};
use optcnn::util::json::Json;

const NETS: [&str; 2] = ["lenet5", "alexnet"];
const DEVICES: [usize; 2] = [2, 4];
const STRATEGIES: [&str; 3] = ["data", "model", "owt"];

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-6 * a.abs().max(b.abs()).max(1.0)
}

/// The acceptance matrix: for lenet5 and alexnet at 2 and 4 devices, the
/// simulator and the executor's planned accounting consume the same plan
/// and report identical xfer/sync byte totals.
#[test]
fn sim_and_exec_report_identical_bytes_from_one_plan() {
    for net in NETS {
        for ndev in DEVICES {
            for strat in STRATEGIES {
                let g = nets::by_name(net, 32 * ndev).unwrap();
                let d = DeviceGraph::p100_cluster(ndev).unwrap();
                let cm = CostModel::new(&g, &d);
                let s = strategies::by_name(strat, &g, ndev).unwrap();
                let plan = ExecutionPlan::build(&cm, &s);

                // the simulator consumes the plan...
                let sim = simulate_plan(&plan, &cm);
                assert!(
                    close(sim.xfer_bytes, plan.xfer_bytes()),
                    "{net}@{ndev}/{strat}: sim xfer {} vs plan {}",
                    sim.xfer_bytes,
                    plan.xfer_bytes()
                );
                assert!(
                    close(sim.sync_bytes, plan.sync_bytes()),
                    "{net}@{ndev}/{strat}: sim sync {} vs plan {}",
                    sim.sync_bytes,
                    plan.sync_bytes()
                );
                assert_eq!(sim.num_transfers, plan.num_transfers());

                // ...the executor's CommStats mirror the same plan...
                let exec = CommStats::planned(&plan);
                assert!(close(exec.xfer_bytes as f64, sim.xfer_bytes));
                assert!(close(exec.sync_bytes as f64, sim.sync_bytes));

                // ...and the cost model's Figure-8 accounting agrees too.
                let cv = comm_volume(&cm, &s);
                assert!(close(cv.xfer_bytes, plan.xfer_bytes()));
                assert!(close(cv.sync_bytes, plan.sync_bytes()));
                assert!(close(plan.comm().total(), cv.total()));
            }
        }
    }
}

/// Plan JSON round-trips exactly: `from_json(to_json(p)) == p`.
#[test]
fn plan_json_roundtrip_is_exact() {
    for net in NETS {
        for ndev in DEVICES {
            for strat in STRATEGIES {
                let g = nets::by_name(net, 32 * ndev).unwrap();
                let d = DeviceGraph::p100_cluster(ndev).unwrap();
                let cm = CostModel::new(&g, &d);
                let s = strategies::by_name(strat, &g, ndev).unwrap();
                let plan = ExecutionPlan::build(&cm, &s);
                let text = plan.to_json().to_string();
                let parsed = Json::parse(&text).expect("plan JSON parses");
                let back = ExecutionPlan::from_json(&parsed).expect("plan JSON loads");
                assert_eq!(back, plan, "{net}@{ndev}/{strat}");
                // and the deserialized plan reports the same totals
                assert_eq!(back.xfer_bytes(), plan.xfer_bytes());
                assert_eq!(back.sync_bytes(), plan.sync_bytes());
            }
        }
    }
}

/// The two simulator entry points — recompute-from-strategy and
/// expand-from-plan — are bit-identical.
#[test]
fn plan_driven_simulation_equals_strategy_driven() {
    for net in NETS {
        for ndev in DEVICES {
            let g = nets::by_name(net, 32 * ndev).unwrap();
            let d = DeviceGraph::p100_cluster(ndev).unwrap();
            let cm = CostModel::new(&g, &d);
            let s = strategies::owt(&g, ndev);
            let plan = ExecutionPlan::build(&cm, &s);
            let a = simulate(&g, &d, &s, &cm);
            let b = simulate_plan(&plan, &cm);
            assert_eq!(a.step_time, b.step_time, "{net}@{ndev}");
            assert_eq!(a.xfer_bytes, b.xfer_bytes);
            assert_eq!(a.sync_bytes, b.sync_bytes);
            assert_eq!(a.num_tasks, b.num_tasks);
        }
    }
}

/// A random small CNN chain with an optional concat branch (mirrors the
/// generator in `properties.rs`).
fn random_net(g: &mut Gen) -> optcnn::graph::CompGraph {
    let mut b = GraphBuilder::new("random");
    let batch = *g.choose(&[4usize, 8]);
    let mut cur = b.input(batch, *g.choose(&[1usize, 3]), 16, 16).unwrap();
    let depth = g.usize_in(1, 4);
    for i in 0..depth {
        if g.bool() && i == 0 {
            let c1 = b
                .conv2d(&format!("bl{i}"), cur, *g.choose(&[4usize, 8]), (3, 3), (1, 1), (1, 1))
                .unwrap();
            let c2 = b
                .conv2d(&format!("br{i}"), cur, *g.choose(&[4usize, 8]), (1, 1), (1, 1), (0, 0))
                .unwrap();
            cur = b.concat(&format!("cat{i}"), &[c1, c2]).unwrap();
        } else {
            cur = b
                .conv2d(&format!("c{i}"), cur, *g.choose(&[4usize, 8]), (3, 3), (1, 1), (1, 1))
                .unwrap();
        }
        cur = b.pool2d(&format!("p{i}"), cur, PoolKind::Max, (2, 2), (2, 2), (0, 0)).unwrap();
    }
    let f = b.fully_connected("fc", cur, 10).unwrap();
    b.softmax("sm", f).unwrap();
    b.finish().unwrap()
}

/// Property: for random nets and random baseline strategies, the plan's
/// scheduled bytes equal the simulator's reported bytes and the cost
/// model's accounting.
#[test]
fn plan_bytes_agree_with_sim_on_random_nets() {
    forall("plan/sim/cost byte parity", 25, |gen| {
        let net = random_net(gen);
        let ndev = *gen.choose(&[2usize, 4]);
        let d = DeviceGraph::p100_cluster(ndev).unwrap();
        let cm = CostModel::new(&net, &d);
        let strat = *gen.choose(&["data", "model", "owt"]);
        let s = strategies::by_name(strat, &net, ndev).unwrap();
        let plan = ExecutionPlan::build(&cm, &s);
        let sim = simulate_plan(&plan, &cm);
        let cv = comm_volume(&cm, &s);
        assert!(close(sim.xfer_bytes, plan.xfer_bytes()), "{strat}@{ndev}");
        assert!(close(sim.sync_bytes, plan.sync_bytes()), "{strat}@{ndev}");
        assert!(close(cv.xfer_bytes, plan.xfer_bytes()), "{strat}@{ndev}");
        assert!(close(cv.sync_bytes, plan.sync_bytes()), "{strat}@{ndev}");
        // JSON round-trip holds on arbitrary graphs too
        let back =
            ExecutionPlan::from_json(&Json::parse(&plan.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, plan);
    });
}
