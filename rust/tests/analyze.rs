//! End-to-end pins for the pre-planning static analysis (DESIGN.md §11):
//!
//! 1. random series-parallel graphs all classify `FullyReducible`, and
//!    the search-cost certificate predicts the exhaustive DFS's
//!    search-tree node count *exactly* (on prune-free tables built so
//!    branch-and-bound can never cut a subtree);
//! 2. all seven builtin networks are fully reducible at 2/4/8 devices,
//!    with the certificate equal to the per-layer `enumerate_configs`
//!    counting twin and its products composed without drift;
//! 3. the memory precheck returns byte-for-byte the `Infeasible`
//!    verdict `CostTables::build_budgeted` would have failed with,
//!    across a budget sweep, without building a single table;
//! 4. a hand-built irreducible multi-branch graph classifies
//!    `Residual`, and its certified residual enumeration matches the
//!    elimination backend's brute-forced final space exactly;
//! 5. `optcnn serve` rejects a plan request whose certified residual
//!    enumeration exceeds `MAX_RESIDUAL_SPACE_LOG2` with the typed
//!    search-space error — while the `{"want":"analyze"}` probe still
//!    answers for the same graph — with zero tables built either way;
//! 6. `Planner::analyze` is observable as table-free through
//!    `SessionStats`.

mod common;

use common::{p100, random_series_parallel};
use optcnn::analyze::{self, Reducibility};
use optcnn::cost::{CostModel, CostTables};
use optcnn::error::OptError;
use optcnn::graph::{CompGraph, GraphBuilder};
use optcnn::memory::MemBudget;
use optcnn::parallel::enumerate_configs;
use optcnn::planner::backend::{Elimination, ExhaustiveDfs, SearchBackend};
use optcnn::planner::serve::{handle_line as serve_handle_line, ServeMetrics};
use optcnn::planner::{Network, PlanService, Planner, MAX_RESIDUAL_SPACE_LOG2};
use optcnn::prop::forall;
use optcnn::util::json::Json;

/// The serving core with a throwaway metrics sink — these tests are
/// about the analyze protocol, not wire latency.
fn handle_line(service: &PlanService, line: &str) -> String {
    serve_handle_line(service, &ServeMetrics::default(), line)
}

/// Cost tables on which branch-and-bound can never prune, so the DFS
/// walks its entire search tree and `visited` becomes exactly
/// predictable from the certificate. Trick: give layer `l`'s config `c`
/// the node cost `weight_l * (C_l - 1 - c)` with `weight_l` the product
/// of all *later* layers' config counts (and no edge tables). A full
/// assignment's total cost is then the rank of its complement in
/// lexicographic enumeration order — strictly decreasing as the DFS
/// enumerates — and any partial prefix's cost is strictly below the
/// best-so-far leaf, so `acc >= best` never fires anywhere.
fn no_prune_tables(g: &CompGraph, ndev: usize) -> CostTables {
    let configs: Vec<_> = g.layers.iter().map(|l| enumerate_configs(l, ndev)).collect();
    let n = configs.len();
    let mut weight = vec![1u128; n];
    for l in (0..n.saturating_sub(1)).rev() {
        weight[l] = weight[l + 1] * configs[l + 1].len() as u128;
    }
    let node_cost = (0..n)
        .map(|l| {
            let c_l = configs[l].len();
            (0..c_l).map(|c| (weight[l] * (c_l - 1 - c) as u128) as f64).collect()
        })
        .collect();
    CostTables { configs, node_cost, edges: vec![], ndev, budget: None }
}

/// `stages` copies of the cross-linked double-diamond from the analyze
/// unit tests, stacked: each stage's two branches feed BOTH of its two
/// joins, so no node ever has degree (1,1) and no parallel edges arise —
/// the elimination fixpoint keeps the whole ladder. All convs are 1x1
/// so shapes stay put; concat widths (2ch, 3ch) reset to `ch` at the
/// next stage's convs.
fn irreducible_ladder(stages: usize, batch: usize, ch: usize, hw: usize) -> CompGraph {
    let mut b = GraphBuilder::new("ladder");
    let mut cur = b.input(batch, ch, hw, hw).unwrap();
    for s in 0..stages {
        let a = b.conv2d(&format!("a{s}"), cur, ch, (1, 1), (1, 1), (0, 0)).unwrap();
        let c = b.conv2d(&format!("c{s}"), cur, ch, (1, 1), (1, 1), (0, 0)).unwrap();
        let j1 = b.add(&format!("j1_{s}"), a, c).unwrap();
        let j2 = b.concat(&format!("j2_{s}"), &[a, c]).unwrap();
        let m1 = b.conv2d(&format!("m1_{s}"), j1, ch, (1, 1), (1, 1), (0, 0)).unwrap();
        let m2 = b.conv2d(&format!("m2_{s}"), j2, ch, (1, 1), (1, 1), (0, 0)).unwrap();
        let t1 = b.add(&format!("t1_{s}"), m1, m2).unwrap();
        let t2 = b.concat(&format!("t2_{s}"), &[m1, m2]).unwrap();
        cur = b.concat(&format!("z{s}"), &[t1, t2]).unwrap();
    }
    let f = b.fully_connected("fc", cur, 10).unwrap();
    b.softmax("sm", f).unwrap();
    b.finish().unwrap()
}

/// Product of certified per-layer counts over `ids`, `None` on overflow
/// — the same composition the certificate claims to have performed.
fn product_over(layer_configs: &[u64], mut ids: impl Iterator<Item = usize>) -> Option<u128> {
    ids.try_fold(1u128, |acc, id| acc.checked_mul(layer_configs[id] as u128))
}

#[test]
fn series_parallel_graphs_reduce_and_certificate_predicts_dfs_exactly() {
    forall("analyze on random series-parallel nets", 8, |g| {
        let net = random_series_parallel(g);
        let ndev = 2;
        let d = p100(ndev);
        let r = analyze::analyze(&net, &d, ndev, None);

        assert_eq!(
            r.reducibility,
            Reducibility::FullyReducible,
            "series-parallel graph `{}` did not fully reduce: kernel {:?}",
            net.name,
            r.kernel
        );
        assert!(r.kernel.nodes.len() <= 2);

        // counting twin: the certificate is exactly what enumeration
        // would materialize, layer for layer
        for (l, layer) in net.layers.iter().enumerate() {
            assert_eq!(
                r.certificate.layer_configs[l],
                enumerate_configs(layer, ndev).len() as u64,
                "layer {l} ({})",
                layer.name
            );
        }

        // certificate == DFS `enumerated`: on prune-free tables the DFS
        // visits its whole search tree, whose node count is the sum of
        // prefix products of the certified per-layer counts (the +1 is
        // the root; the final prefix product is the leaf count).
        let tables = no_prune_tables(&net, ndev);
        let opt = ExhaustiveDfs { budget: None }.search(&tables).unwrap();
        let mut expected_tree = 1u128;
        let mut prefix = 1u128;
        for &c in &r.certificate.layer_configs {
            prefix *= c as u128;
            expected_tree += prefix;
        }
        assert_eq!(
            opt.stats.enumerated as u128, expected_tree,
            "DFS search-tree nodes diverged from the certificate's prediction"
        );
        assert_eq!(opt.stats.space_size, r.certificate.full_space);
        // the complement-rank construction makes the lexicographically
        // last assignment cost exactly 0 — the optimum
        assert_eq!(opt.cost, 0.0, "no-prune tables have a zero-cost optimum by construction");

        // and on *real* tables, the elimination backend's final space is
        // the certified residual enumeration
        let cm = CostModel::new(&net, &d);
        let real = CostTables::build(&cm, ndev).unwrap();
        let elim = Elimination.search(&real).unwrap();
        assert_eq!(elim.stats.final_nodes, r.kernel.nodes.len());
        assert_eq!(elim.stats.space_size, r.certificate.residual_space);
    });
}

#[test]
fn builtins_pin_reducibility_and_certificate_at_2_4_8_devices() {
    for net in Network::ALL {
        for ndev in [2usize, 4, 8] {
            let g = net.graph(32 * ndev).unwrap();
            let d = p100(ndev);
            let r = analyze::analyze(&g, &d, ndev, None);

            // the paper's K=2 claim holds for every benchmark network
            assert_eq!(
                r.reducibility,
                Reducibility::FullyReducible,
                "{net} x{ndev}: kernel {:?}",
                r.kernel
            );
            assert!(r.kernel.nodes.len() <= 2, "{net} x{ndev}");
            assert_eq!(r.errors(), 0, "{net} x{ndev}: {:?}", r.diagnostics);

            // counting twin per layer, then product composition
            for (l, layer) in g.layers.iter().enumerate() {
                assert_eq!(
                    r.certificate.layer_configs[l],
                    enumerate_configs(layer, ndev).len() as u64,
                    "{net} x{ndev} layer {l} ({})",
                    layer.name
                );
            }
            let full = product_over(&r.certificate.layer_configs, 0..g.layers.len());
            assert_eq!(r.certificate.full_space, full, "{net} x{ndev}");
            let resid =
                product_over(&r.certificate.layer_configs, r.kernel.nodes.iter().copied());
            assert_eq!(r.certificate.residual_space, resid, "{net} x{ndev}");

            // log2 fields agree with the exact products when those fit
            if let Some(s) = r.certificate.residual_space {
                assert!(
                    (r.certificate.residual_space_log2 - (s as f64).log2()).abs() < 1e-6,
                    "{net} x{ndev}"
                );
            }
            if let Some(s) = r.certificate.full_space {
                assert!(
                    (r.certificate.full_space_log2 - (s as f64).log2()).abs() < 1e-6,
                    "{net} x{ndev}"
                );
            }
            assert!(r.certificate.residual_space_log2 <= r.certificate.full_space_log2 + 1e-9);
        }
    }
}

#[test]
fn memory_precheck_agrees_with_build_budgeted_verdict() {
    let g = Network::AlexNet.graph(64).unwrap();
    let d = p100(2);
    let cm = CostModel::new(&g, &d);
    for bytes in [1u64, 1_000_000, 100_000_000, 4_000_000_000, u64::MAX] {
        let budget = MemBudget::new(bytes);
        let r = analyze::analyze(&g, &d, 2, Some(budget));
        let mem = r.memory.expect("a budget was supplied");
        for lf in &mem.per_layer {
            assert!(lf.feasible <= lf.configs, "budget {bytes}");
        }

        let verdict = CostTables::build_budgeted(&cm, 2, Some(budget))
            .map(|_| ())
            .map_err(|e| e.to_string());
        match (&mem.infeasible, verdict) {
            (None, Ok(())) => {}
            (Some((layer, overshoot)), Err(msg)) => {
                // byte-for-byte the same typed error
                let expected =
                    OptError::Infeasible { layer: layer.clone(), overshoot: *overshoot }
                        .to_string();
                assert_eq!(msg, expected, "budget {bytes}");
            }
            (precheck, verdict) => panic!(
                "budget {bytes}: precheck said {precheck:?} but build_budgeted said {verdict:?}"
            ),
        }

        // the standalone precheck entry point gives the same yes/no
        let pre = analyze::precheck(&g, 2, Some(budget), f64::INFINITY);
        assert_eq!(pre.is_ok(), mem.infeasible.is_none(), "budget {bytes}");
    }
}

#[test]
fn irreducible_graph_certificate_matches_brute_force_exactly() {
    let g = irreducible_ladder(1, 2, 3, 5);
    let ndev = 2;
    let d = p100(ndev);
    let r = analyze::analyze(&g, &d, ndev, None);

    match r.reducibility {
        Reducibility::Residual { nodes, edges } => {
            assert!(nodes > 2, "kernel has {nodes} nodes");
            assert!(edges > 0);
            assert_eq!(nodes, r.kernel.nodes.len());
            assert_eq!(edges, r.kernel.edges.len());
        }
        Reducibility::FullyReducible => panic!("cross-linked ladder cannot fully reduce"),
    }

    // brute-force the residual enumeration size independently: the
    // product of materialized config-list lengths over surviving nodes
    let brute: u128 = r
        .kernel
        .nodes
        .iter()
        .map(|&id| enumerate_configs(&g.layers[id], ndev).len() as u128)
        .product();
    assert_eq!(r.certificate.residual_space, Some(brute));
    assert_eq!(
        r.certificate.full_space,
        product_over(&r.certificate.layer_configs, 0..g.layers.len())
    );

    // the elimination backend, run for real, brute-forces exactly the
    // certified space — and every evaluated leaf is counted within it
    let cm = CostModel::new(&g, &d);
    let tables = CostTables::build(&cm, ndev).unwrap();
    let opt = Elimination.search(&tables).unwrap();
    assert_eq!(opt.stats.final_nodes, r.kernel.nodes.len());
    assert_eq!(opt.stats.space_size, Some(brute));
    assert!(opt.stats.enumerated >= 1);
    assert!(opt.stats.enumerated as u128 <= brute);
}

#[test]
fn serve_rejects_over_cap_plan_requests_but_analyze_probe_still_answers() {
    // two stages of the ladder at 4 devices certify ~2^70+ residual
    // strategies — far past the service cap, far under u128
    let g = irreducible_ladder(2, 4, 4, 8);
    let ndev = 4;
    let d = p100(ndev);
    let r = analyze::analyze(&g, &d, ndev, None);
    assert!(
        r.certificate.residual_space_log2 > MAX_RESIDUAL_SPACE_LOG2,
        "precondition: ladder must certify over the cap, got 2^{:.1}",
        r.certificate.residual_space_log2
    );

    let service = PlanService::new();
    let spec = g.to_spec().to_string();

    // a plan request for the same graph dies at ingest, before any table
    let reply = handle_line(&service, &format!(r#"{{"graph": {spec}, "devices": {ndev}}}"#));
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "reply: {reply}");
    let err = v.get("error").and_then(Json::as_str).unwrap();
    assert!(err.contains("search space too large"), "unexpected error: {err}");
    assert!(err.contains("2^"), "error should name the certified size: {err}");
    assert_eq!(service.stats().table_builds, 0, "rejection must not build tables");
    assert_eq!(service.stats().searches, 0);

    // the analyze probe is deliberately uncapped — it is how a client
    // discovers the rejection ahead of time
    let probe = format!(r#"{{"want": "analyze", "graph": {spec}, "devices": {ndev}}}"#);
    let reply = handle_line(&service, &probe);
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "reply: {reply}");
    let analysis = v.get("analysis").unwrap();
    assert_eq!(analysis.get("reducibility").and_then(Json::as_str), Some("residual"));
    let cert = analysis.get("certificate").unwrap();
    let log2 = cert.get("residual_space_log2").and_then(Json::as_f64).unwrap();
    assert!((log2 - r.certificate.residual_space_log2).abs() < 1e-9);
    assert_eq!(service.stats().table_builds, 0, "analysis must not build tables");
}

#[test]
fn planner_analyze_is_table_free() {
    let p = Planner::builder(Network::Vgg16).devices(4).mem_limit(u64::MAX).build().unwrap();
    let r = p.analyze();
    assert_eq!(r.ndev, 4);
    assert_eq!(r.reducibility, Reducibility::FullyReducible);
    let mem = r.memory.expect("a session mem limit becomes the analysis budget");
    assert!(mem.infeasible.is_none(), "an unlimited budget cannot be infeasible");
    let stats = p.session_stats();
    assert_eq!(stats.table_builds, 0, "analysis must build no cost tables");
    assert_eq!(stats.searches, 0);
}
