//! End-to-end pins for the cost-table audit (DESIGN.md §12):
//!
//! 1. a **mutation corpus**: for every named [`TableCheck`], at least one
//!    targeted corruption of an honestly built table that fails the audit
//!    with exactly that check — the auditor's checks are falsifiable, not
//!    decorative;
//! 2. all seven builtin networks audit clean at 2/4/8 devices, both
//!    unbudgeted and under a 16 GB budget, and the differential backend
//!    cross-check certifies agreement on every one (release grid);
//! 3. dominance pruning is **exact**: the exhaustive DFS over pruned
//!    tables visits strictly fewer search-tree nodes and returns the
//!    byte-identical optimum;
//! 4. property: random series-parallel graphs built honestly audit clean
//!    at 2/4/8 devices, and pruned-vs-unpruned search is byte-identical.

mod common;

use common::{p100, random_series_parallel};
use optcnn::audit::{audit_tables, cross_check, prune_tables};
use optcnn::cost::{CostModel, CostTables};
use optcnn::device::DeviceGraph;
use optcnn::error::{OptError, TableCheck};
use optcnn::graph::{nets, CompGraph};
use optcnn::memory::MemBudget;
use optcnn::optimizer;
use optcnn::parallel::param_sharding;
use optcnn::planner::backend::{ExhaustiveDfs, SearchBackend};
use optcnn::planner::Network;
use optcnn::prop::forall;

fn lenet(ndev: usize) -> (CompGraph, DeviceGraph) {
    (nets::lenet5(32 * ndev).unwrap(), p100(ndev))
}

/// Assert the audit fails with exactly the named check.
fn expect_check(cm: &CostModel, t: &CostTables, want: TableCheck) {
    match audit_tables(cm, t) {
        Err(OptError::InvalidTables { check, detail }) => {
            assert_eq!(check, want, "wrong check for: {detail}");
            // exit-code contract: the CLI prints `invalid tables [name]: ...`
            let msg = OptError::InvalidTables { check, detail }.to_string();
            assert!(
                msg.contains(&format!("[{}]", want.name())),
                "message must name the check: {msg}"
            );
        }
        Err(other) => panic!("expected invalid tables [{}], got: {other}", want.name()),
        Ok(_) => panic!("mutation expected to fail [{}] audited clean", want.name()),
    }
}

// ---- mutation corpus: each named check must be falsifiable ----

#[test]
fn corpus_infinite_node_cost_fails_finite_costs() {
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    t.node_cost[1][0] = f64::INFINITY;
    expect_check(&cm, &t, TableCheck::FiniteCosts);
}

#[test]
fn corpus_negative_transfer_cost_fails_finite_costs() {
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    t.edges[0].cost[0] = -1e-12;
    expect_check(&cm, &t, TableCheck::FiniteCosts);
}

#[test]
fn corpus_out_of_order_configs_fail_config_canonical() {
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    let l = (0..t.configs.len())
        .find(|&l| t.configs[l].len() >= 2)
        .expect("some layer has at least two configs");
    t.configs[l].swap(0, 1);
    expect_check(&cm, &t, TableCheck::ConfigCanonical);
}

#[test]
fn corpus_duplicated_config_fails_config_canonical() {
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    let l = (0..t.configs.len())
        .find(|&l| t.configs[l].len() >= 2)
        .expect("some layer has at least two configs");
    t.configs[l][1] = t.configs[l][0];
    expect_check(&cm, &t, TableCheck::ConfigCanonical);
}

#[test]
fn corpus_illegal_degree_fails_config_canonical() {
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    // a degree product of 3 can never run on 2 devices
    t.configs[1][0].deg[0] = 3;
    expect_check(&cm, &t, TableCheck::ConfigCanonical);
}

#[test]
fn corpus_device_count_mismatch_fails_config_canonical() {
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    t.ndev = 3;
    expect_check(&cm, &t, TableCheck::ConfigCanonical);
}

#[test]
fn corpus_truncated_edge_row_fails_edge_dims() {
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    t.edges[0].cost.pop();
    expect_check(&cm, &t, TableCheck::EdgeDims);
}

#[test]
fn corpus_swapped_edge_order_fails_edge_dims() {
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    assert!(t.edges.len() >= 2, "lenet5 is a chain of more than two layers");
    t.edges.swap(0, 1);
    expect_check(&cm, &t, TableCheck::EdgeDims);
}

#[test]
fn corpus_underpriced_transfers_fail_lower_bounds() {
    // Zero every transfer entry: some (producer, consumer) pair must move
    // bytes between devices, and free remote bytes violate physics.
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    for e in &mut t.edges {
        for v in &mut e.cost {
            *v = 0.0;
        }
    }
    expect_check(&cm, &t, TableCheck::LowerBounds);
}

#[test]
fn corpus_underpriced_sync_fails_lower_bounds() {
    // Zero the node cost of a replicated parameterized config: its
    // round-trip gradient/parameter exchange cannot be free.
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    let mut found = None;
    for (l, gl) in g.layers.iter().enumerate() {
        for (c, cfg) in t.configs[l].iter().enumerate() {
            if gl.has_params() && param_sharding(gl, cfg).replicas > 1 {
                found = Some((l, c));
            }
        }
    }
    let (l, c) = found.expect("lenet5@2 has a replicated parameterized config");
    t.node_cost[l][c] = 0.0;
    expect_check(&cm, &t, TableCheck::LowerBounds);
}

#[test]
fn corpus_stale_budget_mask_fails_budget_mask() {
    // Claim a 1-byte budget over an unmasked table: the recorded config
    // lists no longer match what the budget admits.
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build(&cm, 2).unwrap();
    t.budget = Some(MemBudget::new(1));
    expect_check(&cm, &t, TableCheck::BudgetMask);
}

#[test]
fn corpus_perturbed_budgeted_entry_fails_budget_mask() {
    // A budgeted table must be *bitwise* the surviving subset of the
    // unbudgeted build — a perturbation too small to trip the lower
    // bounds still fails the subset comparison.
    let (g, d) = lenet(2);
    let cm = CostModel::new(&g, &d);
    let mut t = CostTables::build_budgeted(&cm, 2, Some(MemBudget::new(16 << 30))).unwrap();
    t.node_cost[1][0] += 1.0;
    expect_check(&cm, &t, TableCheck::BudgetMask);
}

#[test]
fn corpus_covers_every_named_check() {
    // The corpus above exercises each TableCheck variant; pin the list so
    // adding a check forces adding a mutation for it.
    assert_eq!(
        TableCheck::ALL.map(|c| c.name()),
        ["finite-costs", "config-canonical", "edge-dims", "lower-bounds", "budget-mask"]
    );
}

// ---- exactness of dominance pruning ----

#[test]
fn pruned_tables_shrink_the_exhaustive_search_and_preserve_its_optimum() {
    // Small builtins where a complete DFS is cheap either way. At least
    // one must certify dominated configs (FC layers' replicated configs);
    // on each that does, the pruned search must visit strictly fewer
    // search-tree nodes and land on the byte-identical optimum.
    let mut reduced_somewhere = false;
    for net in ["minicnn", "lenet5"] {
        let g = nets::by_name(net, 64).unwrap();
        let d = p100(2);
        let cm = CostModel::new(&g, &d);
        let t = CostTables::build(&cm, 2).unwrap();
        let (pt, removed) = prune_tables(&cm, &t);
        if removed == 0 {
            continue;
        }
        let full = ExhaustiveDfs::default().search(&t).unwrap();
        let slim = ExhaustiveDfs::default().search(&pt).unwrap();
        assert_eq!(
            full.cost.to_bits(),
            slim.cost.to_bits(),
            "{net}: {} vs {}",
            full.cost,
            slim.cost
        );
        assert_eq!(full.strategy.configs, slim.strategy.configs, "{net}");
        assert!(
            slim.stats.enumerated < full.stats.enumerated,
            "{net}: pruned DFS visited {} of the full search's {}",
            slim.stats.enumerated,
            full.stats.enumerated
        );
        reduced_somewhere = true;
    }
    assert!(reduced_somewhere, "no small builtin certified a dominated config");
}

// ---- the release grid: every builtin, every device count, both budgets ----

#[test]
#[cfg_attr(debug_assertions, ignore = "heavy grid; the release CI steps run it")]
fn builtin_grid_audits_clean_and_backends_agree() {
    for net in Network::ALL {
        for ndev in [2usize, 4, 8] {
            let g = net.graph(32 * ndev).unwrap();
            let d = p100(ndev);
            let cm = CostModel::new(&g, &d);

            let free = CostTables::build(&cm, ndev).unwrap();
            let report = audit_tables(&cm, &free).unwrap();
            assert_eq!(report.checks.len(), TableCheck::ALL.len(), "{net} x{ndev}");

            let capped =
                CostTables::build_budgeted(&cm, ndev, Some(MemBudget::new(16 << 30))).unwrap();
            audit_tables(&cm, &capped)
                .unwrap_or_else(|e| panic!("{net} x{ndev} under 16 GB: {e}"));

            let c = cross_check(&cm, &free, None).unwrap();
            assert!(c.complete, "{net} x{ndev}");
            assert!(c.kernel_nodes <= 2, "{net} x{ndev}: K = {}", c.kernel_nodes);
        }
    }
}

// ---- property: honest builds audit clean, pruning is exact ----

#[test]
fn honest_series_parallel_builds_audit_clean_and_prune_exactly() {
    forall("audit on random series-parallel nets", 6, |g| {
        let net = random_series_parallel(g);
        for ndev in [2usize, 4, 8] {
            let d = p100(ndev);
            let cm = CostModel::new(&net, &d);
            let t = CostTables::build(&cm, ndev).unwrap();

            let report = audit_tables(&cm, &t)
                .unwrap_or_else(|e| panic!("honest build failed audit at {ndev} devices: {e}"));
            assert_eq!(report.checks.len(), TableCheck::ALL.len());
            assert_eq!(report.configs_total, t.configs.iter().map(|c| c.len()).sum::<usize>());

            let c = cross_check(&cm, &t, None).unwrap();
            assert!(c.complete, "{ndev} devices");

            let (pt, _removed) = prune_tables(&cm, &t);
            let a = optimizer::optimize(&t);
            let b = optimizer::optimize(&pt);
            assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{ndev} devices");
            assert_eq!(a.strategy.configs, b.strategy.configs, "{ndev} devices");
        }

        // a budgeted build of the same graph audits clean too (the mask
        // re-derivation proves it bitwise consistent with the free build)
        let d = p100(2);
        let cm = CostModel::new(&net, &d);
        let t = CostTables::build_budgeted(&cm, 2, Some(MemBudget::new(16 << 30))).unwrap();
        audit_tables(&cm, &t).unwrap();
    });
}
